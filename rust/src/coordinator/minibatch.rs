//! Host-side minibatch training on `ComposeEngine::compose_batch`.
//!
//! The paper's scaling argument is that the embedding layer's parameters
//! fit in memory even when the composed `n × d` input matrix does not —
//! so the trainer must never materialize that matrix. This module closes
//! the loop: a GraphSAGE-style loop ([`MinibatchTrainer`]) draws seed
//! batches from the train split ([`SeedBatcher`]), samples a bounded
//! multi-hop neighborhood per batch ([`NeighborSampler`] →
//! [`MultiHopBlock`], one chained hop per configured fanout), composes
//! **only the outermost hop's rows** with
//! [`ComposeEngine::compose_batch`], runs an L-layer mean-aggregation
//! SAGE head (`h⁽ʲ⁺¹⁾ᵢ = σ(Wⱼ_self·h⁽ʲ⁾ᵢ + Wⱼ_neigh·mean_{k∈N(i)}
//! h⁽ʲ⁾ₖ + bⱼ)`, ReLU between layers, linear logits), and
//! backpropagates layer by layer — chaining the same order-preserving
//! reverse-topology scatter through every hop — through the compose
//! (Eq. 7/11/12) into the embedding tables with a sparse SGD/Adam step
//! ([`Optimizer`]). Peak compose allocation is `block_rows × d`,
//! tracked as [`MinibatchOutcome::peak_compose_rows`] and asserted
//! `< n` by `rust/tests/minibatch.rs`.
//!
//! The head depth is the fanout list's length
//! ([`SamplerConfig::fanouts`]): one fanout (`--fanout 10`) is the
//! classic one-layer head, `--fanouts 10,5` a two-layer head whose
//! hop-0 block feeds the **last** layer. With one layer the math, the
//! parameter names (`head_w_self`/`head_w_neigh`/`head_b`), the RNG
//! streams and therefore the entire trajectory are bit-identical to the
//! pre-multi-hop trainer (`rust/tests/multihop.rs` pins this against a
//! test-local replica of the legacy loop).
//!
//! **Pipelined execution.** By default the trainer overlaps and
//! parallelizes every phase without changing a single bit of the
//! result: a [`BlockPrefetcher`] samples batch *b + 1* on a dedicated
//! thread while batch *b* is stepped (blocks are keyed per
//! `(seed, epoch, batch, layer, node)`, so sampling ahead cannot change
//! them, and they arrive in batch order through a bounded channel with
//! a recycle pool); the step itself fans out on rayon — per-seed
//! forward rows are disjoint, each layer's `dL/dh` uses an
//! order-preserving reverse-topology scatter, embedding gradients
//! accumulate into row-range [`GradBuffer`] shards that merge touch
//! lists in fixed shard order, and the optimizer updates touched rows
//! independently. The `MinibatchOptions { parallel: false, prefetch: 0,
//! .. }` path keeps the serial step in-tree as the oracle;
//! `tests/parallel_train.rs` and `tests/multihop.rs` pin exact
//! (bit-for-bit) loss-trajectory equality between the two at 1 and 4
//! threads, for one- and two-layer heads.
//!
//! **Oracle parity.** [`train_full_batch`] is the same L-layer model
//! trained the classic way — `compose_all`, dense `n × dim` activations
//! per layer — kept as the reference implementation. In the oracle
//! configuration ([`SamplerConfig::oracle`]: every fanout = ∞, one
//! batch = the whole train split, no shuffle) the minibatch path
//! performs the same update: the composed rows are bit-identical
//! (compose-engine parity), per-layer aggregation and every gradient
//! accumulator walk the same row orders (the full-batch trainer replays
//! the oracle block's per-hop discovery order), so the two loss
//! trajectories agree within 1e-5 per epoch (pinned by proptest).
//!
//! **Crash safety.** The trainer walks one global `(epoch, batch)`
//! cursor instead of per-epoch loops, and can snapshot everything that
//! cursor implies — parameter bits, lazy Adam moments, optimizer step
//! count, completed-epoch losses and the in-progress epoch's `f64` loss
//! accumulator — into an atomically-published checkpoint
//! ([`super::checkpoint`]) every N steps and at any failure boundary.
//! Because every random draw is a pure function of
//! `(seed, epoch, batch, …)`, resuming from a checkpoint replays the
//! exact remaining schedule: a killed-and-resumed run produces the same
//! loss trajectory and final tables **bit for bit** as an uninterrupted
//! one, serial or pipelined (`tests/checkpoint.rs`,
//! `tests/crash_resume.rs`).
//!
//! DHE is the one method family not supported here: it has no embedding
//! tables to scatter gradients into (an MLP backward would be needed),
//! and the paper itself could not scale DHE to its largest graph.

use super::checkpoint::{self, CheckpointConfig, Cursor, RunKey};
use super::optim::{GradBuffer, Optimizer, OptimizerKind};
use crate::data::{Dataset, TaskKind};
use crate::embedding::{
    compose, init_params, ComposeEngine, ComposeOptions, EmbeddingPlan, ParamStore,
};
use crate::metrics::{accuracy, binary_auc, hits_at_k, mean_roc_auc};
use crate::sampler::{
    mix_seed, sample_negative, BlockPrefetcher, EdgeBatch, EdgeBatcher, EdgeSplit, Fanouts,
    MultiHopBlock, NeighborSampler, SamplerConfig, SeedBatcher, SeedSource,
};
use crate::util::fault;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use rayon::prelude::*;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Row-range shards per gradient table in the parallel scatter phase —
/// a fixed constant (not the pool size), so the work decomposition and
/// therefore the touch-merge order never depend on thread count.
const SCATTER_SHARDS: usize = 16;

/// Edge fraction held out of the link-prediction loss for validation.
const LP_VAL_FRAC: f64 = 0.05;
/// Edge fraction held out of the link-prediction loss for testing.
const LP_TEST_FRAC: f64 = 0.10;
/// `k` for the link-prediction hits@k evaluation metric.
const LP_HITS_K: usize = 50;

/// How an edge score is decoded from two node representations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeDecoder {
    /// `s(u, v) = ⟨h_u, h_v⟩` — parameter-free.
    Dot,
    /// `s(u, v) = ⟨w, h_u ⊙ h_v⟩ + b` with a learned weight row
    /// (`edge_w`) and bias (`edge_b`) — the Hadamard-MLP decoder.
    Hadamard,
}

impl std::fmt::Display for EdgeDecoder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EdgeDecoder::Dot => write!(f, "dot"),
            EdgeDecoder::Hadamard => write!(f, "hadamard"),
        }
    }
}

/// What the trainer optimizes: the classic node-classification loss, or
/// link prediction over a held-out edge split (per Hashing-Accelerated
/// GNNs for Link Prediction, Wu 2021) — BCE on decoded edge scores,
/// with seeded negative sampling and AUC / hits@k evaluation. Both
/// objectives share the sampler, compose engine, SAGE head, prefetch
/// pipeline and checkpoint machinery; only the loss head differs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Cross-entropy (or multi-label BCE) over labeled seed nodes.
    NodeClassification,
    /// BCE over decoded edge scores, `neg_per_pos` sampled negatives
    /// per held-out positive edge.
    LinkPrediction {
        /// Edge score decoder.
        decoder: EdgeDecoder,
        /// Negatives sampled per positive, per batch.
        neg_per_pos: usize,
    },
}

impl Objective {
    /// True for the link-prediction variants.
    pub fn is_link(&self) -> bool {
        matches!(self, Objective::LinkPrediction { .. })
    }

    /// Parse a CLI-style task tag: `nodeclass` (alias `nc`), `linkpred`
    /// (alias `lp`, dot decoder) or `linkpred-hadamard`. `neg_per_pos`
    /// arrives via its own flag, so start from 1 and adjust with
    /// [`with_neg_per_pos`](Objective::with_neg_per_pos).
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "nodeclass" | "nc" => Ok(Objective::NodeClassification),
            "linkpred" | "lp" | "linkpred-dot" => {
                Ok(Objective::LinkPrediction { decoder: EdgeDecoder::Dot, neg_per_pos: 1 })
            }
            "linkpred-hadamard" => {
                Ok(Objective::LinkPrediction { decoder: EdgeDecoder::Hadamard, neg_per_pos: 1 })
            }
            _ => Err(format!(
                "unknown task '{s}' (expected nodeclass, linkpred or linkpred-hadamard)"
            )),
        }
    }

    /// The same objective with `neg_per_pos` negatives per positive
    /// (no-op for node classification).
    pub fn with_neg_per_pos(self, neg: usize) -> Self {
        match self {
            Objective::LinkPrediction { decoder, .. } => {
                Objective::LinkPrediction { decoder, neg_per_pos: neg }
            }
            nc => nc,
        }
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Objective::NodeClassification => write!(f, "nodeclass"),
            Objective::LinkPrediction { decoder, neg_per_pos } => {
                write!(f, "linkpred({decoder},neg={neg_per_pos})")
            }
        }
    }
}

/// Knobs for a host-side training run (minibatch or full-batch).
#[derive(Debug, Clone)]
pub struct MinibatchOptions {
    /// Training epochs.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f32,
    /// Update rule (SGD, or Adam with lazy sparse moments).
    pub optimizer: OptimizerKind,
    /// Seed for parameter init, epoch shuffles and neighbor draws.
    pub seed: u64,
    /// Print a progress line per epoch.
    pub verbose: bool,
    /// Cross-check the compose engine at startup: full scalar-oracle
    /// parity at small `n·d`, a bounded parallel-vs-serial probe beyond
    /// (the minibatch trainer never materializes `n × d`, not even to
    /// verify itself; the full-batch trainer always uses the full check).
    pub verify_compose: bool,
    /// Run the forward/backward/apply phases of every step on the rayon
    /// pool. The parallel step is engineered to be **bit-identical** to
    /// the serial one (disjoint output ownership, order-preserving
    /// reverse scatter per layer, row-range gradient sharding — see the
    /// module docs), so this knob trades nothing but wall time; `false`
    /// keeps the serial step in-tree as the oracle
    /// (`tests/parallel_train.rs` pins serial ≡ parallel at 1 and 4
    /// threads).
    pub parallel: bool,
    /// Sampled blocks prefetched ahead of the trainer by a dedicated
    /// sampler thread (see [`BlockPrefetcher`]); `0` samples on the
    /// calling thread exactly as the serial loop always has. Prefetching
    /// cannot change results — blocks are keyed per
    /// `(seed, epoch, batch, layer, node)` and delivered in batch order.
    pub prefetch: usize,
    /// Hidden width of the SAGE head's intermediate layers (unused by
    /// one-layer heads, whose single layer maps `d → classes`).
    pub hidden: usize,
    /// Write a versioned model artifact (tables + plan indices + graph,
    /// see [`crate::serve`]) to this directory after training.
    pub save_model: Option<std::path::PathBuf>,
    /// Periodic crash-safe checkpointing (root directory, step period,
    /// retention — see [`CheckpointConfig`]); `None` disables it.
    pub checkpoint: Option<CheckpointConfig>,
    /// Resume from the newest intact checkpoint under
    /// `checkpoint.dir` before training (a no-op when the root holds no
    /// checkpoint yet — the run then starts fresh). Requires
    /// `checkpoint` to be set; refuses checkpoints whose [`RunKey`]
    /// differs from this run's.
    pub resume: bool,
    /// Training objective: node classification (default) or link
    /// prediction over a held-out edge split.
    pub objective: Objective,
}

impl Default for MinibatchOptions {
    fn default() -> Self {
        MinibatchOptions {
            epochs: 20,
            lr: 0.01,
            optimizer: OptimizerKind::Adam,
            seed: 0,
            verbose: false,
            verify_compose: true,
            parallel: true,
            prefetch: 2,
            hidden: 64,
            save_model: None,
            checkpoint: None,
            resume: false,
            objective: Objective::NodeClassification,
        }
    }
}

/// Result of one host-side training run.
#[derive(Debug, Clone)]
pub struct MinibatchOutcome {
    /// Per-epoch mean training loss (seed-weighted; each batch's loss is
    /// measured on the parameters it starts from).
    pub losses: Vec<f64>,
    /// Wall time of each epoch in nanoseconds.
    pub epoch_ns: Vec<u64>,
    /// Validation metric after the final epoch (accuracy or ROC-AUC
    /// for node classification; AUC for link prediction).
    pub val_metric: f64,
    /// Test metric after the final epoch.
    pub test_metric: f64,
    /// Validation hits@k (link prediction only).
    pub val_hits: Option<f64>,
    /// Test hits@k (link prediction only).
    pub test_hits: Option<f64>,
    /// Largest number of rows composed for a single training batch. The
    /// minibatch trainer's memory invariant: strictly less than `n`
    /// whenever batches are smaller than the graph.
    pub peak_compose_rows: usize,
    /// Seed nodes visited per epoch (train-split size).
    pub seeds_per_epoch: usize,
    /// Batches per epoch.
    pub batches_per_epoch: usize,
    /// Total training wall time.
    pub wall: Duration,
}

impl MinibatchOutcome {
    /// One-line summary.
    pub fn row(&self) -> String {
        format!(
            "epochs={} loss {:.4} -> {:.4} val={:.3} test={:.3} peak_rows={} [{:?}]",
            self.losses.len(),
            self.losses.first().copied().unwrap_or(f64::NAN),
            self.losses.last().copied().unwrap_or(f64::NAN),
            self.val_metric,
            self.test_metric,
            self.peak_compose_rows,
            self.wall
        )
    }
}

/// (`W_self`, `W_neigh`, `b`) parameter names per SAGE layer. One-layer
/// heads keep the legacy names (`head_w_self`/`head_w_neigh`/`head_b`),
/// so pre-multi-hop runs, tests and tooling are untouched; deeper heads
/// use `head{l}_*`.
pub(crate) fn head_param_names(layers: usize) -> Vec<(String, String, String)> {
    (0..layers)
        .map(|l| {
            if layers == 1 {
                ("head_w_self".to_string(), "head_w_neigh".to_string(), "head_b".to_string())
            } else {
                (format!("head{l}_w_self"), format!("head{l}_w_neigh"), format!("head{l}_b"))
            }
        })
        .collect()
}

/// `(input, output)` dimensions of SAGE layer `j` in an `layers`-deep
/// head: the first layer reads the composed `d`-dim embeddings, the
/// last emits `classes` logits, everything between is `hidden` wide.
pub(crate) fn layer_dims(
    d: usize,
    classes: usize,
    hidden: usize,
    layers: usize,
    j: usize,
) -> (usize, usize) {
    let din = if j == 0 { d } else { hidden };
    let dout = if j + 1 == layers { classes } else { hidden };
    (din, dout)
}

/// Neighbor-sampled minibatch trainer over a borrowed (dataset, plan).
///
/// Owns the parameters, the optimizer state and all reusable scratch
/// buffers; the compose buffer grows to the largest sampled block and is
/// never `n × d`. Runs are bit-identical across rayon thread counts: the
/// sampler is keyed per `(seed, epoch, batch, layer, node)` and the
/// compose engine is bitwise thread-count-independent.
pub struct MinibatchTrainer<'a> {
    ds: &'a Dataset,
    engine: ComposeEngine<'a>,
    cfg: SamplerConfig,
    opts: MinibatchOptions,
    params: ParamStore,
    opt: Optimizer,
    grads: BTreeMap<String, GradBuffer>,
    source: SeedSource,
    /// SAGE head depth (= `cfg.fanouts.layers()`).
    layers: usize,
    /// Head output width: `classes` for node classification, `hidden`
    /// for link prediction (the last SAGE layer emits node embeddings
    /// an edge decoder scores, not logits).
    out_dim: usize,
    /// Held-out edge split (link prediction only).
    lp_split: Option<EdgeSplit>,
    /// Per-layer head parameter names.
    head: Vec<(String, String, String)>,
    /// Inline sampler for the un-prefetched path, built lazily on first
    /// use: the default pipelined path samples on the prefetch thread
    /// (which owns its own sampler), and the `O(n)` global→local
    /// scratch should not sit allocated twice at large `n`.
    sampler: Option<NeighborSampler<'a>>,
    /// Per-level activations: `acts[0]` is the composed block
    /// (`block_rows × d`, reused across batches), `acts[j + 1]` is
    /// layer j's output rows.
    acts: Vec<Vec<f32>>,
    /// Per-layer neighbor means (`layer_seeds × layer_in_dim`).
    nbars: Vec<Vec<f32>>,
    /// Per-seed `dL/dlogits`.
    glogits: Vec<f32>,
    /// Per-level back-propagated gradients: `dacts[j]` = `dL/dacts[j]`
    /// (`dacts[0]` is the embedding gradient the tables receive).
    dacts: Vec<Vec<f32>>,
    /// One seed's `W_neigh·g` back-signal (widest layer input) — serial
    /// path only.
    dn: Vec<f32>,
    /// Sampler stream seed (shared verbatim with the prefetcher so
    /// prefetched blocks are bit-identical to inline sampling).
    sampler_seed: u64,
    /// Per-seed losses (parallel path: computed concurrently, summed in
    /// seed order so the epoch loss matches the serial path's bits).
    losses_buf: Vec<f64>,
    /// Per-seed `W_self·g` back-signals (parallel path, per layer).
    dself: Vec<f32>,
    /// Per-seed `W_neigh·g` back-signals (parallel path, per layer).
    dnbuf: Vec<f32>,
    /// Per-seed `1 / |sampled neighbors|` (0 when isolated).
    inv_deg: Vec<f32>,
    /// Reverse-topology CSR offsets (`block_rows + 1`, rebuilt per
    /// layer).
    rev_ptr: Vec<u32>,
    /// Reverse-topology fill cursors (scratch for the counting sort).
    rev_cur: Vec<u32>,
    /// Reverse-topology entries: for each block row, the seeds that
    /// scatter into it (ascending), with the row's own seed id doubling
    /// as the "add your own `W_self` signal here" marker.
    rev_idx: Vec<u32>,
    peak_compose_rows: usize,
    /// Completed-epoch mean losses — owned by the trainer (not the
    /// epoch loop) so checkpoints can snapshot them mid-run.
    losses: Vec<f64>,
    /// Completed-epoch wall times (ns).
    epoch_ns: Vec<u64>,
    /// Epoch of the next batch to process (== completed epochs).
    cur_epoch: usize,
    /// Next batch index within `cur_epoch`.
    cur_batch: usize,
    /// In-progress epoch's summed per-seed loss (`f64`, batch order —
    /// checkpointed bit-exactly so a resumed epoch's mean is identical).
    epoch_loss_sum: f64,
    /// Seed nodes consumed so far in the in-progress epoch.
    epoch_seen: usize,
    /// Wall-clock start of the in-progress epoch.
    epoch_t0: Instant,
}

impl<'a> MinibatchTrainer<'a> {
    /// Build a trainer. Fails on DHE plans (no tables to scatter into)
    /// and, when `verify_compose` is on, on compose-engine drift.
    pub fn new(
        ds: &'a Dataset,
        plan: &'a EmbeddingPlan,
        cfg: SamplerConfig,
        opts: MinibatchOptions,
    ) -> Result<Self> {
        if plan.dhe.is_some() {
            bail!("minibatch training does not support DHE (no embedding tables to train)");
        }
        if plan.n != ds.graph.num_nodes() {
            bail!("plan is for n = {} but dataset has {} nodes", plan.n, ds.graph.num_nodes());
        }
        let layers = cfg.fanouts.layers();
        if layers > 1 && opts.hidden == 0 {
            bail!("hidden width must be >= 1 for a {layers}-layer head");
        }
        // Node classification batches the train split; link prediction
        // builds its own held-out edge split and batches positive edges
        // (seed stream 0x5EED5 in both cases, so objectives are
        // independent draws of the same batching machinery).
        let batch_seed = mix_seed(&[opts.seed, 0x5EED5]);
        let (out_dim, lp_split, source) = match opts.objective {
            Objective::NodeClassification => {
                if ds.splits.train.is_empty() {
                    bail!("dataset has no training nodes to batch");
                }
                let batcher =
                    SeedBatcher::new(&ds.splits.train, cfg.batch_size, cfg.shuffle, batch_seed);
                (ds.spec.classes, None, SeedSource::Nodes(batcher))
            }
            Objective::LinkPrediction { neg_per_pos, .. } => {
                if opts.hidden == 0 {
                    bail!("link prediction needs hidden >= 1 (node-embedding width)");
                }
                let split = EdgeSplit::build(
                    &ds.graph,
                    LP_VAL_FRAC,
                    LP_TEST_FRAC,
                    mix_seed(&[opts.seed, 0xED6E5]),
                );
                if split.train.is_empty() {
                    bail!("graph has no training edges to batch");
                }
                let batcher = EdgeBatcher::new(
                    &split.train,
                    cfg.batch_size,
                    cfg.shuffle,
                    neg_per_pos,
                    batch_seed,
                );
                (opts.hidden, Some(split), SeedSource::Edges(batcher))
            }
        };
        let mut params = init_host_params(plan, out_dim, layers, opts.hidden, opts.seed);
        if opts.verify_compose {
            verify_compose_bounded(plan, &params)
                .map_err(|msg| anyhow!("compose engine self-check failed: {msg}"))?;
        }
        let mut grads = make_grad_buffers(plan, out_dim, layers, opts.hidden);
        if let Objective::LinkPrediction { decoder: EdgeDecoder::Hadamard, .. } = opts.objective {
            let mut rng = Rng::seed_from_u64(mix_seed(&[opts.seed, 0xDEC0]));
            let bound = 1.0 / (out_dim as f32).sqrt();
            let w: Vec<f32> = (0..out_dim).map(|_| rng.gen_f32_range(-bound, bound)).collect();
            params.insert("edge_w", vec![1, out_dim], w);
            params.insert("edge_b", vec![1, 1], vec![0.0]);
            grads.insert("edge_w".to_string(), GradBuffer::new(1, out_dim));
            grads.insert("edge_b".to_string(), GradBuffer::new(1, 1));
        }
        let sampler_seed = mix_seed(&[opts.seed, 0x54AFF]);
        let mut opt = Optimizer::new(opts.optimizer, opts.lr);
        opt.parallel = opts.parallel;
        let head = head_param_names(layers);
        Ok(MinibatchTrainer {
            ds,
            engine: ComposeEngine::new(plan),
            cfg,
            opts,
            params,
            opt,
            grads,
            source,
            layers,
            out_dim,
            lp_split,
            head,
            sampler: None,
            acts: vec![Vec::new(); layers + 1],
            nbars: vec![Vec::new(); layers],
            glogits: Vec::new(),
            dacts: vec![Vec::new(); layers],
            dn: Vec::new(),
            sampler_seed,
            losses_buf: Vec::new(),
            dself: Vec::new(),
            dnbuf: Vec::new(),
            inv_deg: Vec::new(),
            rev_ptr: Vec::new(),
            rev_cur: Vec::new(),
            rev_idx: Vec::new(),
            peak_compose_rows: 0,
            losses: Vec::new(),
            epoch_ns: Vec::new(),
            cur_epoch: 0,
            cur_batch: 0,
            epoch_loss_sum: 0.0,
            epoch_seen: 0,
            epoch_t0: Instant::now(),
        })
    }

    /// The trained parameters (embedding tables + head).
    pub fn params(&self) -> &ParamStore {
        &self.params
    }

    /// SAGE head depth (= fanout list length).
    pub fn layers(&self) -> usize {
        self.layers
    }

    /// Largest number of rows composed for a single training batch so far.
    pub fn peak_compose_rows(&self) -> usize {
        self.peak_compose_rows
    }

    /// Serialize the current parameters (tables + head), the plan's
    /// static indices and the graph into a versioned model artifact at
    /// `dir` (see [`crate::serve`]). Callable at any point;
    /// [`train`](MinibatchTrainer::train) invokes it automatically
    /// when `opts.save_model` is set.
    pub fn save_artifact(&self, dir: &std::path::Path) -> Result<crate::serve::ModelManifest> {
        crate::serve::save_artifact(
            dir,
            self.ds,
            self.engine.plan(),
            &self.params,
            self.layers,
            self.opts.hidden,
        )
    }

    /// Compose one sampled multi-hop block and step on it: the shared
    /// body of the inline and prefetched epoch loops. Returns the
    /// block's summed loss and how many loss terms it contributed
    /// (seeds for node classification, pos + neg edges for link
    /// prediction) so epoch means stay correctly weighted.
    fn process_block(&mut self, mhb: &MultiHopBlock) -> (f64, usize) {
        debug_assert_eq!(mhb.num_hops(), self.layers, "block depth != head depth");
        let d = self.engine.plan().d;
        let rows = mhb.num_rows();
        self.peak_compose_rows = self.peak_compose_rows.max(rows);
        grow(&mut self.acts[0], rows * d);
        // one plan resolution per step; the sampler guarantees every id
        // is < n, so the per-call bounds pre-scan is skipped
        let prepared = self.engine.prepare(&self.params);
        prepared.compose_into_unchecked(&mhb.outer().nodes, &mut self.acts[0][..rows * d]);
        // link prediction re-derives the batch's edges from the cursor
        // (the block only carries the deduped seed list); the edge
        // batcher is a pure function of (epoch, batch), so this matches
        // the seeds the prefetcher sampled bit-for-bit
        let eb = match &self.source {
            SeedSource::Nodes(_) => None,
            SeedSource::Edges(b) => {
                Some(b.batch(&self.ds.graph, self.cur_epoch, self.cur_batch))
            }
        };
        match eb {
            None => (self.step_block(mhb, None), mhb.num_seeds()),
            Some(eb) => {
                debug_assert_eq!(
                    &mhb.hop(0).nodes[..mhb.num_seeds()],
                    &eb.seeds[..],
                    "sampled block and edge batch disagree on seeds"
                );
                (self.step_block(mhb, Some(&eb)), eb.num_edges())
            }
        }
    }

    /// This run's [`RunKey`] — what checkpoints are stamped with, and
    /// what resume validates a checkpoint against.
    pub fn run_key(&self) -> RunKey {
        RunKey {
            dataset: self.ds.spec.name.to_string(),
            method: self.engine.plan().method.name(),
            fanouts: self.cfg.fanouts.to_string(),
            batch_size: self.cfg.batch_size,
            shuffle: self.cfg.shuffle,
            optimizer: match self.opts.optimizer {
                OptimizerKind::Sgd => "sgd".to_string(),
                OptimizerKind::Adam => "adam".to_string(),
            },
            lr_bits: self.opts.lr.to_bits(),
            hidden: self.opts.hidden,
            seed: self.opts.seed,
            epochs: self.opts.epochs,
            objective: self.opts.objective.to_string(),
        }
    }

    /// Process one batch at the cursor: step on the block, advance the
    /// cursor, close the epoch at its last batch, checkpoint when due.
    /// The `trainer.step` fault site fires *before* the step, so an
    /// injected failure (or abort) lands exactly at a batch boundary.
    fn run_batch(&mut self, mhb: &MultiHopBlock) -> Result<()> {
        fault::hit("trainer.step").with_context(|| {
            format!("stepping epoch {} batch {}", self.cur_epoch, self.cur_batch)
        })?;
        let (loss, seen) = self.process_block(mhb);
        self.epoch_loss_sum += loss;
        self.epoch_seen += seen;
        self.cur_batch += 1;
        if self.cur_batch == self.source.num_batches() {
            self.finish_epoch()?;
        }
        self.checkpoint_if_due()
    }

    /// Close the in-progress epoch: record its mean loss and wall time,
    /// move the cursor to the next epoch's first batch.
    fn finish_epoch(&mut self) -> Result<()> {
        let loss = self.epoch_loss_sum / self.epoch_seen as f64;
        if !loss.is_finite() {
            bail!("non-finite training loss at epoch {}", self.cur_epoch);
        }
        self.losses.push(loss);
        self.epoch_ns.push(self.epoch_t0.elapsed().as_nanos() as u64);
        if self.opts.verbose {
            println!("  epoch {:>4}  loss {loss:.4}", self.cur_epoch + 1);
        }
        self.cur_epoch += 1;
        self.cur_batch = 0;
        self.epoch_loss_sum = 0.0;
        self.epoch_seen = 0;
        self.epoch_t0 = Instant::now();
        Ok(())
    }

    /// Write a periodic checkpoint when one is configured and the
    /// optimizer step count hits the period.
    fn checkpoint_if_due(&mut self) -> Result<()> {
        let due = match &self.opts.checkpoint {
            Some(cfg) => cfg.every > 0 && self.opt.step_count() % cfg.every as u64 == 0,
            None => false,
        };
        if due {
            self.checkpoint_now()?;
        }
        Ok(())
    }

    /// Snapshot the full trainer state (params, moments, cursor, loss
    /// history and accumulator) into an atomically-published checkpoint
    /// under the configured root. No-op without a checkpoint config.
    pub fn checkpoint_now(&mut self) -> Result<()> {
        let Some(cfg) = self.opts.checkpoint.clone() else {
            return Ok(());
        };
        let run = self.run_key();
        let cursor = Cursor {
            epoch: self.cur_epoch,
            batch: self.cur_batch,
            global_step: self.opt.step_count(),
            epoch_seen: self.epoch_seen,
            peak_compose_rows: self.peak_compose_rows,
        };
        checkpoint::save_checkpoint(
            &cfg.dir,
            cfg.keep,
            &run,
            &cursor,
            &self.params,
            &self.opt,
            &self.losses,
            &self.epoch_ns,
            self.epoch_loss_sum,
        )?;
        Ok(())
    }

    /// Restore the newest intact checkpoint under the configured root,
    /// bit-installing parameters, Adam moments, the optimizer step
    /// count, the cursor and the loss history. Fresh-run no-op when the
    /// root is empty; fails when the checkpoint belongs to a different
    /// run or its tensors do not match this run's shapes.
    fn maybe_resume(&mut self) -> Result<()> {
        if !self.opts.resume {
            return Ok(());
        }
        let Some(cfg) = self.opts.checkpoint.clone() else {
            bail!("--resume requires a checkpoint directory");
        };
        let Some((ck, warnings)) = checkpoint::load_latest(&cfg.dir)? else {
            return Ok(());
        };
        for w in &warnings {
            eprintln!("warning: {w}");
        }
        ck.manifest.run.ensure_matches(&self.run_key())?;
        if ck.manifest.param_names != self.params.names() {
            bail!(
                "checkpoint '{}' holds tensors {:?}, this run has {:?}",
                ck.name,
                ck.manifest.param_names,
                self.params.names()
            );
        }
        for (name, shape, data) in &ck.params {
            if self.params.shape(name) != shape.as_slice() {
                bail!(
                    "checkpoint tensor '{}' has shape {:?}, this run expects {:?}",
                    name,
                    shape,
                    self.params.shape(name)
                );
            }
            self.params.get_mut(name).copy_from_slice(data);
        }
        for (name, m, v) in ck.moments {
            let want = self.params.get(&name).len();
            if m.len() != want {
                bail!("checkpoint moments for '{name}' hold {} values, expected {want}", m.len());
            }
            self.opt.restore_moments(&name, m, v);
        }
        self.opt.set_step_count(ck.manifest.cursor.global_step);
        self.cur_epoch = ck.manifest.cursor.epoch;
        self.cur_batch = ck.manifest.cursor.batch;
        self.epoch_seen = ck.manifest.cursor.epoch_seen;
        self.peak_compose_rows = ck.manifest.cursor.peak_compose_rows;
        self.epoch_loss_sum = ck.loss_accum;
        self.losses = ck.losses;
        self.epoch_ns = ck.epoch_ns;
        let _ = checkpoint::sweep_stale_temps(&cfg.dir);
        eprintln!(
            "resumed from checkpoint '{}' at epoch {} batch {} (step {})",
            ck.name, self.cur_epoch, self.cur_batch, ck.manifest.cursor.global_step
        );
        Ok(())
    }

    /// The cursor-driven loop with inline sampling (the un-prefetched
    /// path — [`advance_to_epoch`](MinibatchTrainer::advance_to_epoch)
    /// overlaps sampling on a prefetch thread instead when
    /// `opts.prefetch > 0`). Runs until `epochs` epochs are complete.
    fn run_inline_to(&mut self, epochs: usize) -> Result<()> {
        if self.sampler.is_none() && self.cur_epoch < epochs {
            let ds = self.ds;
            let sampler =
                NeighborSampler::multi_hop(&ds.graph, &self.cfg.fanouts, self.sampler_seed);
            self.sampler = Some(sampler);
        }
        let mut mhb = MultiHopBlock::default();
        while self.cur_epoch < epochs {
            let epoch = self.cur_epoch;
            let batches = self.source.epoch_batches(&self.ds.graph, epoch);
            while self.cur_epoch == epoch {
                let bi = self.cur_batch;
                let sampler = self.sampler.as_mut().expect("inline sampler initialized above");
                sampler.sample_multi_into(&batches[bi], epoch, bi, &mut mhb);
                self.run_batch(&mhb)?;
            }
        }
        Ok(())
    }

    /// Run the training loop forward until `target` epochs are complete
    /// (clamped to `opts.epochs`; no-op when the cursor is already
    /// there). With `opts.prefetch > 0` a dedicated sampler thread
    /// materializes upcoming blocks while the current one is stepped;
    /// otherwise sampling is inline. Because blocks are pure functions
    /// of `(seed, epoch, batch, layer, node)`, driving the loop one
    /// epoch at a time through this method — as the sharded trainer
    /// does between halo exchanges — replays exactly the batches a
    /// single [`train`](MinibatchTrainer::train) call would, bit for
    /// bit, on both engine paths.
    pub fn advance_to_epoch(&mut self, target: usize) -> Result<()> {
        let epochs = target.min(self.opts.epochs);
        if self.opts.prefetch > 0 && self.cur_epoch < epochs {
            let ds = self.ds;
            let source = self.source.clone();
            let fans = self.cfg.fanouts.clone();
            let (seed, depth) = (self.sampler_seed, self.opts.prefetch);
            let start = (self.cur_epoch, self.cur_batch);
            std::thread::scope(|scope| -> Result<()> {
                let stream = BlockPrefetcher::spawn(
                    scope,
                    &ds.graph,
                    source,
                    fans,
                    seed,
                    epochs,
                    start,
                    depth,
                );
                while self.cur_epoch < epochs {
                    let block = stream.recv()?;
                    self.run_batch(&block)?;
                    stream.recycle(block);
                }
                Ok(())
            })
        } else {
            self.run_inline_to(epochs)
        }
    }

    /// Completed-epoch mean losses so far (one entry per finished epoch).
    pub fn losses(&self) -> &[f64] {
        &self.losses
    }

    /// Completed-epoch wall times so far (ns, one entry per epoch).
    pub fn completed_epoch_ns(&self) -> &[u64] {
        &self.epoch_ns
    }

    /// Epoch of the next batch to process (== completed epochs).
    pub fn cur_epoch(&self) -> usize {
        self.cur_epoch
    }

    /// Seed nodes (or positive edges) consumed per epoch.
    pub fn seeds_per_epoch(&self) -> usize {
        self.source.num_seeds()
    }

    /// Batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.source.num_batches()
    }

    /// Mutable access to the parameter tables — the sharded trainer's
    /// halo-exchange hook. Overwriting rows between epochs is safe (the
    /// trainer holds no stale copies), but callers own the determinism
    /// of what they write.
    pub(crate) fn params_mut(&mut self) -> &mut ParamStore {
        &mut self.params
    }

    /// Train to `opts.epochs` epochs (from the resumed cursor, if any),
    /// then evaluate val/test. With `opts.prefetch > 0` a dedicated
    /// sampler thread materializes upcoming blocks while the current one
    /// is stepped. On a failure mid-run the trainer first writes a
    /// best-effort checkpoint at the last completed batch boundary, so
    /// `--resume` loses no finished work even on unplanned aborts.
    pub fn train(&mut self) -> Result<MinibatchOutcome> {
        let t0 = Instant::now();
        self.maybe_resume()?;
        self.epoch_t0 = Instant::now();
        let run = self.advance_to_epoch(self.opts.epochs);
        if let Err(e) = run {
            // the cursor sits at the last completed batch boundary
            // unless the epoch close itself failed (non-finite loss —
            // nothing worth resuming then)
            if self.opts.checkpoint.is_some() && self.cur_batch < self.source.num_batches() {
                match self.checkpoint_now() {
                    Ok(()) => eprintln!(
                        "checkpointed at epoch {} batch {} before aborting; rerun with \
                         --resume to continue",
                        self.cur_epoch, self.cur_batch
                    ),
                    Err(ce) => eprintln!("warning: failure-boundary checkpoint failed: {ce:#}"),
                }
            }
            return Err(e);
        }
        let ds = self.ds;
        let (val_metric, test_metric, val_hits, test_hits) = match &self.lp_split {
            None => {
                (self.evaluate(&ds.splits.val)?, self.evaluate(&ds.splits.test)?, None, None)
            }
            Some(split) => {
                let (vauc, vhits) = self.evaluate_link(&split.val)?;
                let (tauc, thits) = self.evaluate_link(&split.test)?;
                (vauc, tauc, Some(vhits), Some(thits))
            }
        };
        if let Some(dir) = self.opts.save_model.clone() {
            self.save_artifact(&dir)?;
        }
        Ok(MinibatchOutcome {
            losses: self.losses.clone(),
            epoch_ns: self.epoch_ns.clone(),
            val_metric,
            test_metric,
            val_hits,
            test_hits,
            peak_compose_rows: self.peak_compose_rows,
            seeds_per_epoch: self.source.num_seeds(),
            batches_per_epoch: self.source.num_batches(),
            wall: t0.elapsed(),
        })
    }

    /// Score a fold with the current parameters, composed chunk by
    /// chunk. Evaluation uses **full** neighborhoods at every hop
    /// (standard GraphSAGE practice), so one chunk's block is bounded by
    /// the chunk's L-hop neighborhood (and by `n` via dedup) — larger
    /// than a training block and outside the `peak_compose_rows`
    /// invariant, but still far from `n × d` on bounded-degree graphs.
    /// Returns accuracy (multi-class) or mean ROC-AUC (multi-label).
    pub fn evaluate(&self, fold: &[u32]) -> Result<f64> {
        let ds = self.ds;
        let classes = ds.spec.classes;
        let scores = self.embed_nodes(fold)?;
        // both branches hand the shared metric fns fold-local labels
        // and indices, so minibatch eval can never drift from the
        // metric implementations the full-batch paths use
        let local: Vec<u32> = (0..fold.len() as u32).collect();
        let metric = match ds.spec.task {
            TaskKind::MultiClass => {
                let labels_sub: Vec<u32> = fold.iter().map(|&i| ds.labels[i as usize]).collect();
                accuracy(&scores, classes, &labels_sub, &local)
            }
            TaskKind::MultiLabel => {
                let labels_sub: Vec<u32> = fold
                    .iter()
                    .flat_map(|&i| {
                        let i = i as usize;
                        ds.labels[i * classes..(i + 1) * classes].iter().copied()
                    })
                    .collect();
                mean_roc_auc(&scores, classes, &labels_sub, &local)
            }
        };
        Ok(metric)
    }

    /// Run the frozen model over `fold`, composed and forwarded chunk
    /// by chunk with **full** neighborhoods at every hop, returning the
    /// head's output rows (`fold.len() × out_dim`, fold order): logits
    /// for node classification, node embeddings for link prediction.
    fn embed_nodes(&self, fold: &[u32]) -> Result<Vec<f32>> {
        if fold.is_empty() {
            bail!("empty evaluation fold");
        }
        let ds = self.ds;
        let d = self.engine.plan().d;
        let out_dim = self.out_dim;
        let layers = self.layers;
        let hidden = self.opts.hidden;
        let chunk = self.cfg.batch_size.max(1);
        let mut sampler = NeighborSampler::multi_hop(&ds.graph, &Fanouts::all(layers), 0);
        let mut mhb = MultiHopBlock::default();
        let mut x: Vec<f32> = Vec::new();
        let mut cur: Vec<f32> = Vec::new();
        let mut nxt: Vec<f32> = Vec::new();
        let mut nb = vec![0f32; if layers > 1 { d.max(hidden) } else { d }];
        let mut scores = vec![0f32; fold.len() * out_dim];
        let heads: Vec<(&[f32], &[f32], &[f32])> = self
            .head
            .iter()
            .map(|(ws, wn, b)| (self.params.get(ws), self.params.get(wn), self.params.get(b)))
            .collect();
        // parameters are frozen during evaluation: resolve the plan once
        // for the whole fold instead of once per chunk
        let prepared = self.engine.prepare(&self.params);
        let mut done = 0usize;
        for (ci, seeds) in fold.chunks(chunk).enumerate() {
            sampler.sample_multi_into(seeds, 0, ci, &mut mhb);
            let rows = mhb.num_rows();
            grow(&mut x, rows * d);
            prepared.compose_into_unchecked(&mhb.outer().nodes, &mut x[..rows * d]);
            for j in 0..layers {
                let blk = mhb.hop(layers - 1 - j);
                let s = blk.num_seeds;
                let (din, dout) = layer_dims(d, out_dim, hidden, layers, j);
                grow(&mut nxt, s * dout);
                let input: &[f32] = if j == 0 { &x } else { &cur };
                let (w_self, w_neigh, bias) = heads[j];
                for si in 0..s {
                    mean_rows(&mut nb[..din], input, blk.neighbors_of(si));
                    sage_affine_row(
                        &input[si * din..(si + 1) * din],
                        &nb[..din],
                        w_self,
                        w_neigh,
                        bias,
                        &mut nxt[si * dout..(si + 1) * dout],
                    );
                }
                if j + 1 < layers {
                    for v in nxt[..s * dout].iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                std::mem::swap(&mut cur, &mut nxt);
            }
            let s = mhb.num_seeds();
            scores[done * out_dim..(done + s) * out_dim].copy_from_slice(&cur[..s * out_dim]);
            done += s;
        }
        Ok(scores)
    }

    /// Score a held-out edge fold: one seeded negative per positive
    /// (keyed by the positive's fold index — stream `0xEBA1` — so the
    /// eval set is fixed across epochs, thread counts and resumes),
    /// full-neighborhood embeddings for every endpoint, then
    /// `(AUC, hits@{LP_HITS_K})` over the decoded scores.
    pub fn evaluate_link(&self, pos: &[(u32, u32)]) -> Result<(f64, f64)> {
        if pos.is_empty() {
            bail!("empty edge evaluation fold");
        }
        let ds = self.ds;
        let negs: Vec<(u32, u32)> = pos
            .iter()
            .enumerate()
            .map(|(i, &e)| {
                let mut rng =
                    Rng::seed_from_u64(mix_seed(&[self.opts.seed, 0xEBA1, i as u64]));
                sample_negative(&ds.graph, &mut rng, e)
            })
            .collect();
        // first-occurrence-deduped endpoint list (the sampler rejects
        // duplicate seeds), embedded once and indexed per edge
        let mut local: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
        let mut nodes: Vec<u32> = Vec::new();
        let mut row = |u: u32| -> usize {
            *local.entry(u).or_insert_with(|| {
                nodes.push(u);
                (nodes.len() - 1) as u32
            }) as usize
        };
        let pos_local: Vec<(usize, usize)> = pos.iter().map(|&(u, v)| (row(u), row(v))).collect();
        let neg_local: Vec<(usize, usize)> = negs.iter().map(|&(u, v)| (row(u), row(v))).collect();
        let h = self.embed_nodes(&nodes)?;
        let dim = self.out_dim;
        let decoder = match self.opts.objective {
            Objective::LinkPrediction { decoder, .. } => decoder,
            Objective::NodeClassification => bail!("evaluate_link on a node-classification run"),
        };
        let score = |&(a, b): &(usize, usize)| -> f32 {
            let hu = &h[a * dim..(a + 1) * dim];
            let hv = &h[b * dim..(b + 1) * dim];
            match decoder {
                EdgeDecoder::Dot => hu.iter().zip(hv).map(|(x, y)| x * y).sum(),
                EdgeDecoder::Hadamard => {
                    let w = self.params.get("edge_w");
                    let bias = self.params.get("edge_b")[0];
                    bias + hu.iter().zip(hv).zip(w).map(|((x, y), wk)| wk * x * y).sum::<f32>()
                }
            }
        };
        let pos_scores: Vec<f32> = pos_local.iter().map(&score).collect();
        let neg_scores: Vec<f32> = neg_local.iter().map(&score).collect();
        Ok((
            binary_auc(&pos_scores, &neg_scores),
            hits_at_k(&pos_scores, &neg_scores, LP_HITS_K),
        ))
    }

    /// Forward + backward + optimizer step on one composed block
    /// (`self.acts[0]` must hold the outer hop's composed rows).
    /// Returns the summed loss (per seed for node classification, per
    /// edge for link prediction — `eb` carries the batch's localized
    /// edges then). Dispatches to the serial oracle step or the
    /// bit-identical parallel step per `opts.parallel`.
    fn step_block(&mut self, mhb: &MultiHopBlock, eb: Option<&EdgeBatch>) -> f64 {
        if self.opts.parallel {
            self.step_block_parallel(mhb, eb)
        } else {
            self.step_block_serial(mhb, eb)
        }
    }

    /// The single-threaded step — kept in-tree as the oracle the
    /// parallel step is pinned against (`tests/parallel_train.rs`,
    /// `tests/multihop.rs`). With one layer this is, operation for
    /// operation, the pre-multi-hop trainer's step.
    fn step_block_serial(&mut self, mhb: &MultiHopBlock, eb: Option<&EdgeBatch>) -> f64 {
        let plan = self.engine.plan();
        let d = plan.d;
        let classes = self.out_dim;
        let layers = self.layers;
        let hidden = self.opts.hidden;
        let s0 = mhb.num_seeds();

        // ---- forward: SAGE layer j aggregates with hop L-1-j ----
        for j in 0..layers {
            let blk = mhb.hop(layers - 1 - j);
            let s = blk.num_seeds;
            let (din, dout) = layer_dims(d, classes, hidden, layers, j);
            grow(&mut self.nbars[j], s * din);
            let (alo, ahi) = self.acts.split_at_mut(j + 1);
            let input: &[f32] = &alo[j];
            let out = &mut ahi[0];
            grow(out, s * dout);
            let nbar = &mut self.nbars[j];
            for si in 0..s {
                mean_rows(&mut nbar[si * din..(si + 1) * din], input, blk.neighbors_of(si));
            }
            let w_self = self.params.get(&self.head[j].0);
            let w_neigh = self.params.get(&self.head[j].1);
            let bias = self.params.get(&self.head[j].2);
            for si in 0..s {
                sage_affine_row(
                    &input[si * din..(si + 1) * din],
                    &nbar[si * din..(si + 1) * din],
                    w_self,
                    w_neigh,
                    bias,
                    &mut out[si * dout..(si + 1) * dout],
                );
            }
            if j + 1 < layers {
                for v in out[..s * dout].iter_mut() {
                    *v = v.max(0.0);
                }
            }
        }

        // ---- loss + dL/d(head output) ----
        grow(&mut self.glogits, s0 * classes);
        let mut loss_sum = 0f64;
        match eb {
            // node classification: mean CE/BCE over the batch's seeds
            None => {
                let gscale = match self.ds.spec.task {
                    TaskKind::MultiClass => 1.0 / s0 as f32,
                    TaskKind::MultiLabel => 1.0 / (s0 * classes) as f32,
                };
                let seeds_blk = mhb.hop(0);
                let logits = &self.acts[layers];
                for si in 0..s0 {
                    let node = seeds_blk.nodes[si] as usize;
                    let lrow = &logits[si * classes..(si + 1) * classes];
                    let grow_row = &mut self.glogits[si * classes..(si + 1) * classes];
                    loss_sum += loss_and_grad_row(
                        self.ds.spec.task,
                        &self.ds.labels,
                        node,
                        lrow,
                        grow_row,
                        gscale,
                    );
                }
            }
            // link prediction: mean BCE over the batch's decoded edges
            Some(eb) => {
                self.glogits[..s0 * classes].fill(0.0);
                loss_sum = lp_edge_loss(
                    lp_decoder(self.opts.objective),
                    &self.params,
                    &self.acts[layers],
                    classes,
                    eb,
                    &mut self.glogits,
                    &mut self.grads,
                );
            }
        }

        // ---- backward, outermost layer first ----
        grow(&mut self.dn, if layers > 1 { d.max(hidden) } else { d });
        for j in (0..layers).rev() {
            let blk = mhb.hop(layers - 1 - j);
            let s = blk.num_seeds;
            let rows = blk.num_rows();
            let (din, dout) = layer_dims(d, classes, hidden, layers, j);
            let (dlo, dhi) = self.dacts.split_at_mut(j + 1);
            // ReLU backward: the layer's output had an activation iff it
            // is not the logits layer
            if j + 1 < layers {
                let act_out = &self.acts[j + 1];
                for (gv, &a) in dhi[0][..s * dout].iter_mut().zip(&act_out[..s * dout]) {
                    if a <= 0.0 {
                        *gv = 0.0;
                    }
                }
            }
            let g: &[f32] = if j + 1 == layers {
                &self.glogits[..s * dout]
            } else {
                &dhi[0][..s * dout]
            };

            // ---- head gradients (seed-ascending adds) ----
            {
                let input = &self.acts[j];
                let nbar = &self.nbars[j];
                let gb = self.grads.get_mut(&self.head[j].0).expect("head w_self grads");
                for si in 0..s {
                    let grow_row = &g[si * dout..(si + 1) * dout];
                    let xs = &input[si * din..(si + 1) * din];
                    for (a, &xa) in xs.iter().enumerate() {
                        gb.add_row(a, xa, grow_row);
                    }
                }
                let gb = self.grads.get_mut(&self.head[j].1).expect("head w_neigh grads");
                for si in 0..s {
                    let grow_row = &g[si * dout..(si + 1) * dout];
                    let nb = &nbar[si * din..(si + 1) * din];
                    for (a, &na) in nb.iter().enumerate() {
                        gb.add_row(a, na, grow_row);
                    }
                }
                let gb = self.grads.get_mut(&self.head[j].2).expect("head bias grads");
                for si in 0..s {
                    gb.add_row(0, 1.0, &g[si * dout..(si + 1) * dout]);
                }
            }

            // ---- back-signal into this layer's input rows ----
            {
                let dh_in = &mut dlo[j];
                grow(dh_in, rows * din);
                dh_in[..rows * din].fill(0.0);
                let w_self = self.params.get(&self.head[j].0);
                let w_neigh = self.params.get(&self.head[j].1);
                for si in 0..s {
                    let grow_row = &g[si * dout..(si + 1) * dout];
                    for a in 0..din {
                        let ws = &w_self[a * dout..(a + 1) * dout];
                        let wn = &w_neigh[a * dout..(a + 1) * dout];
                        let mut acc_s = 0f32;
                        let mut acc_n = 0f32;
                        for ((&gj, wsj), wnj) in grow_row.iter().zip(ws).zip(wn) {
                            acc_s += gj * wsj;
                            acc_n += gj * wnj;
                        }
                        dh_in[si * din + a] += acc_s;
                        self.dn[a] = acc_n;
                    }
                    let nbs = blk.neighbors_of(si);
                    if !nbs.is_empty() {
                        let inv = 1.0 / nbs.len() as f32;
                        for &r in nbs {
                            let dst = &mut dh_in[r as usize * din..(r as usize + 1) * din];
                            for (o, v) in dst.iter_mut().zip(&self.dn[..din]) {
                                *o += inv * v;
                            }
                        }
                    }
                }
            }
        }

        // ---- scatter into embedding tables (block-row order) ----
        {
            let outer = mhb.outer();
            let dx = &self.dacts[0];
            for (r, &node) in outer.nodes.iter().enumerate() {
                let gv = &dx[r * d..(r + 1) * d];
                scatter_embedding_grad(plan, &self.params, node as usize, gv, &mut self.grads);
            }
        }

        // ---- optimizer step (BTreeMap order: deterministic) ----
        self.opt.begin_step();
        for (name, gb) in self.grads.iter_mut() {
            self.opt.apply(name, self.params.get_mut(name), gb);
            gb.clear();
        }
        loss_sum
    }

    /// The rayon-parallel step. Produces the **same bits** as
    /// [`step_block_serial`](MinibatchTrainer::step_block_serial) at any
    /// thread count, by preserving the serial per-element accumulation
    /// order everywhere floats meet, layer by layer:
    ///
    /// * per-seed forward rows (means, affine outputs, loss grads) are
    ///   disjoint; per-seed losses land in a buffer summed in seed
    ///   order; the ReLU and its backward mask are elementwise;
    /// * head-weight gradients shard over **W's rows**: each element's
    ///   contributions still arrive in ascending-seed order;
    /// * each layer's `dL/dh` runs in two phases — per-seed
    ///   back-signals into disjoint rows, then a reverse-topology
    ///   scatter in which each block row replays its incoming
    ///   contributions in ascending iteration order (the row's own
    ///   `W_self` signal merged at its serial position via the
    ///   self-marker);
    /// * embedding-table gradients shard over **destination rows**
    ///   ([`GradBuffer::sharded_accumulate`]): every shard scans block
    ///   rows in order, so per-element order is block-row ascending,
    ///   exactly as the serial scatter;
    /// * the optimizer updates touched rows independently (order-free).
    fn step_block_parallel(&mut self, mhb: &MultiHopBlock, eb: Option<&EdgeBatch>) -> f64 {
        let plan = self.engine.plan();
        let d = plan.d;
        let classes = self.out_dim;
        let layers = self.layers;
        let hidden = self.opts.hidden;
        let s0 = mhb.num_seeds();

        // ---- forward: fused per-seed rows, loss fused into the last
        // layer exactly as the one-layer engine always has ----
        for j in 0..layers {
            let blk = mhb.hop(layers - 1 - j);
            let s = blk.num_seeds;
            let (din, dout) = layer_dims(d, classes, hidden, layers, j);
            grow(&mut self.nbars[j], s * din);
            let (alo, ahi) = self.acts.split_at_mut(j + 1);
            let input: &[f32] = &alo[j];
            let out = &mut ahi[0];
            grow(out, s * dout);
            let w_self = self.params.get(&self.head[j].0);
            let w_neigh = self.params.get(&self.head[j].1);
            let bias = self.params.get(&self.head[j].2);
            if j + 1 < layers {
                let nbar_rows = self.nbars[j][..s * din].par_chunks_mut(din);
                let out_rows = out[..s * dout].par_chunks_mut(dout);
                nbar_rows.zip(out_rows).enumerate().for_each(|(si, (nb, orow))| {
                    mean_rows(nb, input, blk.neighbors_of(si));
                    sage_affine_row(
                        &input[si * din..(si + 1) * din],
                        nb,
                        w_self,
                        w_neigh,
                        bias,
                        orow,
                    );
                    for v in orow.iter_mut() {
                        *v = v.max(0.0);
                    }
                });
            } else if eb.is_none() {
                let gscale = match self.ds.spec.task {
                    TaskKind::MultiClass => 1.0 / s as f32,
                    TaskKind::MultiLabel => 1.0 / (s * classes) as f32,
                };
                grow(&mut self.glogits, s * dout);
                if self.losses_buf.len() < s {
                    self.losses_buf.resize(s, 0.0);
                }
                let labels = &self.ds.labels;
                let task = self.ds.spec.task;
                let nodes = &blk.nodes;
                let nbar_rows = self.nbars[j][..s * din].par_chunks_mut(din);
                let out_rows = out[..s * dout].par_chunks_mut(dout);
                let glog_rows = self.glogits[..s * dout].par_chunks_mut(dout);
                let loss_cells = self.losses_buf[..s].par_iter_mut();
                let fwd = nbar_rows.zip(out_rows).zip(glog_rows).zip(loss_cells).enumerate();
                fwd.for_each(|(si, (((nb, orow), grow_row), loss))| {
                    mean_rows(nb, input, blk.neighbors_of(si));
                    sage_affine_row(
                        &input[si * din..(si + 1) * din],
                        nb,
                        w_self,
                        w_neigh,
                        bias,
                        orow,
                    );
                    let node = nodes[si] as usize;
                    *loss = loss_and_grad_row(task, labels, node, orow, grow_row, gscale);
                });
            } else {
                // link prediction: parallel per-seed embedding rows (no
                // activation, no fused loss — the edge loss below walks
                // edges, not seeds)
                let nbar_rows = self.nbars[j][..s * din].par_chunks_mut(din);
                let out_rows = out[..s * dout].par_chunks_mut(dout);
                nbar_rows.zip(out_rows).enumerate().for_each(|(si, (nb, orow))| {
                    mean_rows(nb, input, blk.neighbors_of(si));
                    sage_affine_row(
                        &input[si * din..(si + 1) * din],
                        nb,
                        w_self,
                        w_neigh,
                        bias,
                        orow,
                    );
                });
            }
        }
        let loss_sum: f64 = match eb {
            // seed-order sum: the exact f64 additions of the serial loop
            None => self.losses_buf[..s0].iter().sum(),
            // the edge loss is a single edge-order walk — shared with
            // the serial step, so the two paths agree bit for bit
            Some(eb) => {
                grow(&mut self.glogits, s0 * classes);
                self.glogits[..s0 * classes].fill(0.0);
                lp_edge_loss(
                    lp_decoder(self.opts.objective),
                    &self.params,
                    &self.acts[layers],
                    classes,
                    eb,
                    &mut self.glogits,
                    &mut self.grads,
                )
            }
        };

        // ---- backward, outermost layer first ----
        for j in (0..layers).rev() {
            let blk = mhb.hop(layers - 1 - j);
            let s = blk.num_seeds;
            let rows = blk.num_rows();
            let (din, dout) = layer_dims(d, classes, hidden, layers, j);
            let (dlo, dhi) = self.dacts.split_at_mut(j + 1);
            if j + 1 < layers {
                // ReLU mask, elementwise — same values as the serial mask
                let act_out = &self.acts[j + 1];
                dhi[0][..s * dout]
                    .par_iter_mut()
                    .zip(act_out[..s * dout].par_iter())
                    .for_each(|(gv, &a)| {
                        if a <= 0.0 {
                            *gv = 0.0;
                        }
                    });
            }
            let g: &[f32] = if j + 1 == layers {
                &self.glogits[..s * dout]
            } else {
                &dhi[0][..s * dout]
            };

            // ---- head gradients (sharded over W's din rows) ----
            {
                let input = &self.acts[j];
                let nbar = &self.nbars[j];
                let gb = self.grads.get_mut(&self.head[j].0).expect("head w_self grads");
                gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                    for si in 0..s {
                        let grow_row = &g[si * dout..(si + 1) * dout];
                        let xs = &input[si * din..(si + 1) * din];
                        for a in sh.rows() {
                            sh.add_row(a, xs[a], grow_row);
                        }
                    }
                });
                let gb = self.grads.get_mut(&self.head[j].1).expect("head w_neigh grads");
                gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                    for si in 0..s {
                        let grow_row = &g[si * dout..(si + 1) * dout];
                        let nb = &nbar[si * din..(si + 1) * din];
                        for a in sh.rows() {
                            sh.add_row(a, nb[a], grow_row);
                        }
                    }
                });
                // one bias row: serial, preserving the seed-order adds
                let gb = self.grads.get_mut(&self.head[j].2).expect("head bias grads");
                for si in 0..s {
                    gb.add_row(0, 1.0, &g[si * dout..(si + 1) * dout]);
                }
            }

            // ---- dL/dh phase 1: per-seed back-signals ----
            grow(&mut self.dself, s * din);
            grow(&mut self.dnbuf, s * din);
            {
                let w_self = self.params.get(&self.head[j].0);
                let w_neigh = self.params.get(&self.head[j].1);
                let ds_rows = self.dself[..s * din].par_chunks_mut(din);
                let dn_rows = self.dnbuf[..s * din].par_chunks_mut(din);
                ds_rows.zip(dn_rows).enumerate().for_each(|(si, (ds_row, dn_row))| {
                    let grow_row = &g[si * dout..(si + 1) * dout];
                    for a in 0..din {
                        let ws = &w_self[a * dout..(a + 1) * dout];
                        let wn = &w_neigh[a * dout..(a + 1) * dout];
                        let mut acc_s = 0f32;
                        let mut acc_n = 0f32;
                        for ((&gj, wsj), wnj) in grow_row.iter().zip(ws).zip(wn) {
                            acc_s += gj * wsj;
                            acc_n += gj * wnj;
                        }
                        ds_row[a] = acc_s;
                        dn_row[a] = acc_n;
                    }
                });
            }
            if self.inv_deg.len() < s {
                self.inv_deg.resize(s, 0.0);
            }
            for (si, inv) in self.inv_deg[..s].iter_mut().enumerate() {
                let deg = blk.neighbors_of(si).len();
                *inv = if deg == 0 { 0.0 } else { 1.0 / deg as f32 };
            }

            // ---- dL/dh phase 2: order-preserving reverse scatter ----
            // Counting-sort the hop topology into row-major incoming
            // lists. Appending while walking seeds in ascending order
            // keeps every row's list ascending; a seed row's own entry
            // (the self-marker, value == row id — impossible for a
            // topology entry, the graph has no self loops) lands exactly
            // where the serial loop added its `W_self` signal.
            self.rev_ptr.clear();
            self.rev_ptr.resize(rows + 1, 0);
            for &r in &blk.neigh_idx {
                self.rev_ptr[r as usize + 1] += 1;
            }
            for si in 0..s {
                self.rev_ptr[si + 1] += 1; // self-marker slot
            }
            for i in 0..rows {
                self.rev_ptr[i + 1] += self.rev_ptr[i];
            }
            let total = self.rev_ptr[rows] as usize;
            self.rev_cur.clear();
            self.rev_cur.extend_from_slice(&self.rev_ptr[..rows]);
            if self.rev_idx.len() < total {
                self.rev_idx.resize(total, 0);
            }
            for si in 0..s {
                let cur = self.rev_cur[si] as usize;
                self.rev_idx[cur] = si as u32;
                self.rev_cur[si] += 1;
                for &r in blk.neighbors_of(si) {
                    let cur = self.rev_cur[r as usize] as usize;
                    self.rev_idx[cur] = si as u32;
                    self.rev_cur[r as usize] += 1;
                }
            }
            {
                let dh_in = &mut dlo[j];
                grow(dh_in, rows * din);
                let rev_ptr = &self.rev_ptr;
                let rev_idx = &self.rev_idx;
                let dself = &self.dself;
                let dnb = &self.dnbuf;
                let inv = &self.inv_deg;
                dh_in[..rows * din].par_chunks_mut(din).enumerate().for_each(|(r, dst)| {
                    dst.fill(0.0);
                    for &sj in &rev_idx[rev_ptr[r] as usize..rev_ptr[r + 1] as usize] {
                        let sj = sj as usize;
                        if sj == r {
                            // the row's own W_self signal
                            for (o, v) in dst.iter_mut().zip(&dself[sj * din..(sj + 1) * din]) {
                                *o += v;
                            }
                        } else {
                            let w = inv[sj];
                            for (o, v) in dst.iter_mut().zip(&dnb[sj * din..(sj + 1) * din]) {
                                *o += w * v;
                            }
                        }
                    }
                });
            }
        }

        // ---- embedding-table scatter (destination-row sharding) ----
        let outer = mhb.outer();
        let dx = &self.dacts[0];
        let nodes = &outer.nodes;
        if let Some(pos) = &plan.position {
            for (j, table) in pos.tables.iter().enumerate() {
                let z = &pos.z[j];
                let dj = table.cols;
                let gb = self.grads.get_mut(&table.name).expect("position grads");
                gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                    for (r, &node) in nodes.iter().enumerate() {
                        let row = z[node as usize] as usize;
                        if sh.contains(row) {
                            sh.add_row(row, 1.0, &dx[r * d..r * d + dj]);
                        }
                    }
                });
            }
        }
        if let Some(nx) = &plan.node {
            let h = nx.h;
            let idx = &nx.node_major;
            let x_table = self.params.get(&nx.table.name);
            let y = nx.learned_weights.then(|| self.params.get("node_y"));
            let gb = self.grads.get_mut(&nx.table.name).expect("node_x grads");
            gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                for (r, &node) in nodes.iter().enumerate() {
                    let i = node as usize;
                    let gv = &dx[r * d..(r + 1) * d];
                    for t in 0..h {
                        let row = idx[i * h + t] as usize;
                        if sh.contains(row) {
                            let w = y.map_or(1.0, |y| y[i * h + t]);
                            sh.add_row(row, w, gv);
                        }
                    }
                }
            });
            if nx.learned_weights {
                // node_y rows are block nodes — unique, one writer each
                let gb = self.grads.get_mut("node_y").expect("node_y grads");
                gb.sharded_accumulate(SCATTER_SHARDS, |sh| {
                    for (r, &node) in nodes.iter().enumerate() {
                        let i = node as usize;
                        if sh.contains(i) {
                            let gv = &dx[r * d..(r + 1) * d];
                            for t in 0..h {
                                let row = idx[i * h + t] as usize;
                                let xrow = &x_table[row * d..(row + 1) * d];
                                let dot: f32 = xrow.iter().zip(gv).map(|(a, b)| a * b).sum();
                                sh.add_at(i, t, dot);
                            }
                        }
                    }
                });
            }
        }

        // ---- optimizer step (BTreeMap order; rows update in parallel) ----
        self.opt.begin_step();
        for (name, gb) in self.grads.iter_mut() {
            self.opt.apply(name, self.params.get_mut(name), gb);
            gb.clear();
        }
        loss_sum
    }
}

/// Grow a scratch buffer to at least `len` elements (never shrinks —
/// steady-state steps reuse the largest block's allocation).
fn grow(buf: &mut Vec<f32>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

/// Train the same L-layer model full-batch over `compose_all` — the
/// reference trainer the minibatch path is pinned against, and the only
/// host path that materializes the full `n × dim` activation matrices.
///
/// In the oracle configuration ([`SamplerConfig::oracle`] with the same
/// `layers`) the minibatch trainer reproduces this loss trajectory
/// within 1e-5 per epoch: the forward values per row are independent of
/// iteration order, and every shared accumulator here (loss sum, head
/// gradients, back-signal scatters, embedding scatter) deliberately
/// walks nodes in the oracle multi-hop block's per-hop row order —
/// train seeds in split order, then each hop's frontier in discovery
/// order — so the two paths agree to float associativity.
pub fn train_full_batch(
    ds: &Dataset,
    plan: &EmbeddingPlan,
    opts: &MinibatchOptions,
    layers: usize,
) -> Result<MinibatchOutcome> {
    if plan.dhe.is_some() {
        bail!("full-batch host training does not support DHE (no embedding tables to train)");
    }
    if layers == 0 {
        bail!("at least one SAGE layer required");
    }
    if layers > 1 && opts.hidden == 0 {
        bail!("hidden width must be >= 1 for a {layers}-layer head");
    }
    if opts.objective.is_link() {
        bail!(
            "full-batch training supports node classification only \
             (use the minibatch trainer for link prediction)"
        );
    }
    let n = plan.n;
    let d = plan.d;
    let classes = ds.spec.classes;
    if n != ds.graph.num_nodes() {
        bail!("plan is for n = {} but dataset has {} nodes", n, ds.graph.num_nodes());
    }
    let head = head_param_names(layers);
    let mut params = init_host_params(plan, classes, layers, opts.hidden, opts.seed);
    if opts.verify_compose {
        compose::self_check(plan, &params, 1e-5)
            .map_err(|msg| anyhow!("compose engine self-check failed: {msg}"))?;
    }
    let engine = ComposeEngine::new(plan);
    let mut opt = Optimizer::new(opts.optimizer, opts.lr);
    let mut grads = make_grad_buffers(plan, classes, layers, opts.hidden);
    let train = &ds.splits.train;

    // Oracle row orders, one list per hop depth: order[0] is the train
    // split, order[l + 1] appends the nodes first discovered at hop
    // l + 1 (scanning the previous list in order, each adjacency in CSR
    // order) — exactly the per-hop node order of the all-fanout
    // multi-hop block, which is what keeps every accumulation below in
    // the minibatch oracle's float order.
    let mut order: Vec<Vec<u32>> = Vec::with_capacity(layers + 1);
    {
        let mut seen = vec![false; n];
        let first: Vec<u32> = train.to_vec();
        for &u in &first {
            seen[u as usize] = true;
        }
        order.push(first);
        for l in 0..layers {
            let mut nxt = order[l].clone();
            for &u in &order[l] {
                for &v in ds.graph.mem().neighbors(u) {
                    if !seen[v as usize] {
                        seen[v as usize] = true;
                        nxt.push(v);
                    }
                }
            }
            order.push(nxt);
        }
    }

    // dense per-level buffers: level 0 is the composed matrix the
    // minibatch path never builds, level j is layer j-1's output
    let level_dim = |lvl: usize| -> usize {
        if lvl == 0 {
            d
        } else if lvl == layers {
            classes
        } else {
            opts.hidden
        }
    };
    let mut h: Vec<Vec<f32>> = (0..=layers).map(|lvl| vec![0f32; n * level_dim(lvl)]).collect();
    let mut dh: Vec<Vec<f32>> = (0..=layers).map(|lvl| vec![0f32; n * level_dim(lvl)]).collect();
    // per-layer neighbor means, filled by the forward pass and reused
    // by the W_neigh-gradient loop (same memory class as `h`)
    let mut nbars: Vec<Vec<f32>> = (0..layers).map(|lvl| vec![0f32; n * level_dim(lvl)]).collect();
    let mut dn = vec![0f32; d.max(opts.hidden)];
    let gscale = match ds.spec.task {
        TaskKind::MultiClass => 1.0 / train.len() as f32,
        TaskKind::MultiLabel => 1.0 / (train.len() * classes) as f32,
    };
    let t0 = Instant::now();
    let mut losses = Vec::with_capacity(opts.epochs);
    let mut epoch_ns = Vec::with_capacity(opts.epochs);
    for epoch in 0..opts.epochs {
        let e0 = Instant::now();
        engine.compose_all_into(&params, &mut h[0]);
        forward_dense(ds, &params, &head, d, classes, opts.hidden, layers, &mut h, &mut nbars);

        // ---- loss + dL/dlogits over train seeds (split order) ----
        let mut loss_sum = 0f64;
        {
            let top = &h[layers];
            let dtop = &mut dh[layers];
            let task = ds.spec.task;
            for &i in train {
                let iu = i as usize;
                let lrow = &top[iu * classes..(iu + 1) * classes];
                let grow_row = &mut dtop[iu * classes..(iu + 1) * classes];
                loss_sum += loss_and_grad_row(task, &ds.labels, iu, lrow, grow_row, gscale);
            }
        }

        // ---- backward, layer by layer, in oracle row order ----
        for j in (0..layers).rev() {
            let (din, dout) = layer_dims(d, classes, opts.hidden, layers, j);
            let seeds = &order[layers - 1 - j];
            let (dlo, dhi) = dh.split_at_mut(j + 1);
            let g_out = &mut dhi[0];
            if j + 1 < layers {
                // ReLU mask on exactly the rows the minibatch step masks
                let act = &h[j + 1];
                for &u in seeds {
                    let base = u as usize * dout;
                    for (gv, &a) in
                        g_out[base..base + dout].iter_mut().zip(&act[base..base + dout])
                    {
                        if a <= 0.0 {
                            *gv = 0.0;
                        }
                    }
                }
            }
            let g_out: &[f32] = g_out;
            {
                let input = &h[j];
                let gb = grads.get_mut(&head[j].0).expect("head w_self grads");
                for &u in seeds {
                    let uu = u as usize;
                    let grow_row = &g_out[uu * dout..(uu + 1) * dout];
                    let xs = &input[uu * din..(uu + 1) * din];
                    for (a, &xa) in xs.iter().enumerate() {
                        gb.add_row(a, xa, grow_row);
                    }
                }
                let gb = grads.get_mut(&head[j].1).expect("head w_neigh grads");
                let nbar = &nbars[j];
                for &u in seeds {
                    let uu = u as usize;
                    let grow_row = &g_out[uu * dout..(uu + 1) * dout];
                    let nb = &nbar[uu * din..(uu + 1) * din];
                    for (a, &na) in nb.iter().enumerate() {
                        gb.add_row(a, na, grow_row);
                    }
                }
                let gb = grads.get_mut(&head[j].2).expect("head bias grads");
                for &u in seeds {
                    let uu = u as usize;
                    gb.add_row(0, 1.0, &g_out[uu * dout..(uu + 1) * dout]);
                }
            }
            {
                let dh_in = &mut dlo[j];
                let w_self = params.get(&head[j].0);
                let w_neigh = params.get(&head[j].1);
                for &u in seeds {
                    let uu = u as usize;
                    let grow_row = &g_out[uu * dout..(uu + 1) * dout];
                    for a in 0..din {
                        let ws = &w_self[a * dout..(a + 1) * dout];
                        let wn = &w_neigh[a * dout..(a + 1) * dout];
                        let mut acc_s = 0f32;
                        let mut acc_n = 0f32;
                        for ((&gj, wsj), wnj) in grow_row.iter().zip(ws).zip(wn) {
                            acc_s += gj * wsj;
                            acc_n += gj * wnj;
                        }
                        dh_in[uu * din + a] += acc_s;
                        dn[a] = acc_n;
                    }
                    let nbs = ds.graph.mem().neighbors(u);
                    if !nbs.is_empty() {
                        let inv = 1.0 / nbs.len() as f32;
                        for &v in nbs {
                            let vu = v as usize;
                            let dst = &mut dh_in[vu * din..(vu + 1) * din];
                            for (o, sig) in dst.iter_mut().zip(&dn[..din]) {
                                *o += inv * sig;
                            }
                        }
                    }
                }
            }
        }

        // ---- embedding scatter (outermost oracle order) ----
        for &u in &order[layers] {
            let uu = u as usize;
            let gv = &dh[0][uu * d..(uu + 1) * d];
            scatter_embedding_grad(plan, &params, uu, gv, &mut grads);
        }
        opt.begin_step();
        for (name, gb) in grads.iter_mut() {
            opt.apply(name, params.get_mut(name), gb);
            gb.clear();
        }
        for buf in dh.iter_mut() {
            buf.fill(0.0);
        }
        let loss = loss_sum / train.len() as f64;
        if !loss.is_finite() {
            bail!("non-finite training loss at epoch {epoch}");
        }
        losses.push(loss);
        epoch_ns.push(e0.elapsed().as_nanos() as u64);
        if opts.verbose {
            println!("  epoch {:>4}  loss {loss:.4}  (full batch)", epoch + 1);
        }
    }

    // ---- final full-matrix evaluation ----
    engine.compose_all_into(&params, &mut h[0]);
    forward_dense(ds, &params, &head, d, classes, opts.hidden, layers, &mut h, &mut nbars);
    let scores = &h[layers];
    let (val_metric, test_metric) = match ds.spec.task {
        TaskKind::MultiClass => (
            accuracy(scores, classes, &ds.labels, &ds.splits.val),
            accuracy(scores, classes, &ds.labels, &ds.splits.test),
        ),
        TaskKind::MultiLabel => (
            mean_roc_auc(scores, classes, &ds.labels, &ds.splits.val),
            mean_roc_auc(scores, classes, &ds.labels, &ds.splits.test),
        ),
    };
    if let Some(dir) = &opts.save_model {
        crate::serve::save_artifact(dir, ds, plan, &params, layers, opts.hidden)?;
    }
    Ok(MinibatchOutcome {
        losses,
        epoch_ns,
        val_metric,
        test_metric,
        val_hits: None,
        test_hits: None,
        peak_compose_rows: n,
        seeds_per_epoch: train.len(),
        batches_per_epoch: 1,
        wall: t0.elapsed(),
    })
}

/// Dense L-layer SAGE forward over every node: `h[0]` must hold the
/// composed `n × d` matrix; fills `h[1..=layers]` and the per-layer
/// neighbor-mean matrices `nbars[j]` (`n × din_j`, reused by the
/// backward pass's `W_neigh` gradients). Per-row values are
/// independent of iteration order, so this matches the minibatch
/// forward bit for bit on shared rows.
#[allow(clippy::too_many_arguments)]
fn forward_dense(
    ds: &Dataset,
    params: &ParamStore,
    head: &[(String, String, String)],
    d: usize,
    classes: usize,
    hidden: usize,
    layers: usize,
    h: &mut [Vec<f32>],
    nbars: &mut [Vec<f32>],
) {
    let n = ds.graph.num_nodes();
    for j in 0..layers {
        let (din, dout) = layer_dims(d, classes, hidden, layers, j);
        let (hlo, hhi) = h.split_at_mut(j + 1);
        let input = &hlo[j];
        let out = &mut hhi[0];
        let nbar = &mut nbars[j];
        let w_self = params.get(&head[j].0);
        let w_neigh = params.get(&head[j].1);
        let bias = params.get(&head[j].2);
        for i in 0..n {
            let nb = &mut nbar[i * din..(i + 1) * din];
            mean_rows(nb, input, ds.graph.mem().neighbors(i as u32));
            sage_affine_row(
                &input[i * din..(i + 1) * din],
                nb,
                w_self,
                w_neigh,
                bias,
                &mut out[i * dout..(i + 1) * dout],
            );
            if j + 1 < layers {
                for v in out[i * dout..(i + 1) * dout].iter_mut() {
                    *v = v.max(0.0);
                }
            }
        }
    }
}

/// Startup compose verification that respects the minibatch memory
/// budget: at small scale (`n·d` ≤ ~4M elements) run the full
/// [`compose::self_check`] against the scalar oracle; beyond that the
/// oracle itself would materialize `n × d`, so fall back to a bounded
/// probe — a ≤4k-row strided `compose_batch` must be bit-identical
/// between the parallel and serial engine paths (the engine's
/// thread-count-determinism contract, `O(probe × d)` memory).
fn verify_compose_bounded(plan: &EmbeddingPlan, params: &ParamStore) -> Result<(), String> {
    const FULL_CHECK_MAX_ELEMS: usize = 1 << 22;
    if plan.n * plan.d <= FULL_CHECK_MAX_ELEMS {
        return compose::self_check(plan, params, 1e-5);
    }
    let stride = (plan.n / 4096).max(1);
    let probe: Vec<u32> = (0..plan.n as u32).step_by(stride).collect();
    let popts = ComposeOptions { parallel: true, ..Default::default() };
    let sopts = ComposeOptions { parallel: false, ..Default::default() };
    let par = ComposeEngine::with_options(plan, popts).compose_batch(params, &probe);
    let ser = ComposeEngine::with_options(plan, sopts).compose_batch(params, &probe);
    if par != ser {
        return Err("parallel and serial compose_batch diverge on the probe batch".into());
    }
    Ok(())
}

/// Embedding tables (via `embedding::init_params`) plus the L-layer
/// SAGE head: per layer, `W_self`/`W_neigh` uniform ±1/√(layer input
/// dim) and a zero bias, drawn in layer order from one stream keyed by
/// `seed` — so a one-layer head's draws are exactly the pre-multi-hop
/// trainer's.
pub(crate) fn init_host_params(
    plan: &EmbeddingPlan,
    classes: usize,
    layers: usize,
    hidden: usize,
    seed: u64,
) -> ParamStore {
    let mut store = init_params(plan, seed);
    let mut rng = Rng::seed_from_u64(mix_seed(&[seed, 0x6EAD]));
    for (l, (wsn, wnn, bn)) in head_param_names(layers).iter().enumerate() {
        let (din, dout) = layer_dims(plan.d, classes, hidden, layers, l);
        let a = 1.0 / (din as f32).sqrt();
        let w_self: Vec<f32> = (0..din * dout).map(|_| rng.gen_f32_range(-a, a)).collect();
        let w_neigh: Vec<f32> = (0..din * dout).map(|_| rng.gen_f32_range(-a, a)).collect();
        store.insert(wsn, vec![din, dout], w_self);
        store.insert(wnn, vec![din, dout], w_neigh);
        store.insert(bn, vec![1, dout], vec![0.0; dout]);
    }
    store
}

/// One [`GradBuffer`] per trainable table (embedding tables + the
/// L-layer head).
fn make_grad_buffers(
    plan: &EmbeddingPlan,
    classes: usize,
    layers: usize,
    hidden: usize,
) -> BTreeMap<String, GradBuffer> {
    let mut grads = BTreeMap::new();
    for t in plan.param_shapes() {
        grads.insert(t.name.clone(), GradBuffer::new(t.rows, t.cols));
    }
    for (l, (wsn, wnn, bn)) in head_param_names(layers).iter().enumerate() {
        let (din, dout) = layer_dims(plan.d, classes, hidden, layers, l);
        grads.insert(wsn.clone(), GradBuffer::new(din, dout));
        grads.insert(wnn.clone(), GradBuffer::new(din, dout));
        grads.insert(bn.clone(), GradBuffer::new(1, dout));
    }
    grads
}

/// Write into `dst` the mean of the given `rows` of the row-major
/// matrix `mat` (row width = `dst.len()`); zero when `rows` is empty.
/// Sums in `rows` order — both trainers and both eval paths share this
/// one implementation, so aggregation bits can never diverge between
/// them (the oracle-parity contract leans on that).
pub(crate) fn mean_rows(dst: &mut [f32], mat: &[f32], rows: &[u32]) {
    let d = dst.len();
    dst.fill(0.0);
    for &r in rows {
        let src = &mat[r as usize * d..(r as usize + 1) * d];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += s;
        }
    }
    if !rows.is_empty() {
        let inv = 1.0 / rows.len() as f32;
        for o in dst.iter_mut() {
            *o *= inv;
        }
    }
}

/// `out = bias + W_self^T·xs + W_neigh^T·nbar` for one row of one SAGE
/// layer (`W ∈ R^{din×dout}` row-major; `dout = out.len()`). Shared by
/// every forward path so affine bits can never diverge between them.
pub(crate) fn sage_affine_row(
    xs: &[f32],
    nbar: &[f32],
    w_self: &[f32],
    w_neigh: &[f32],
    bias: &[f32],
    out: &mut [f32],
) {
    let dout = out.len();
    out.copy_from_slice(bias);
    for (a, (&xa, &na)) in xs.iter().zip(nbar).enumerate() {
        let ws = &w_self[a * dout..(a + 1) * dout];
        let wn = &w_neigh[a * dout..(a + 1) * dout];
        for ((o, wsj), wnj) in out.iter_mut().zip(ws).zip(wn) {
            *o += xa * wsj + na * wnj;
        }
    }
}

/// Per-seed loss and `dL/dlogits` (written to `glog`, scaled by
/// `scale`): softmax cross-entropy for multi-class, stable
/// BCE-with-logits (mean over tasks) for multi-label.
fn loss_and_grad_row(
    task: TaskKind,
    labels: &[u32],
    node: usize,
    logits: &[f32],
    glog: &mut [f32],
    scale: f32,
) -> f64 {
    let classes = logits.len();
    match task {
        TaskKind::MultiClass => {
            let label = labels[node] as usize;
            let max = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
            let mut sum = 0f32;
            for (g, &x) in glog.iter_mut().zip(logits) {
                let e = (x - max).exp();
                *g = e;
                sum += e;
            }
            let inv = scale / sum;
            for g in glog.iter_mut() {
                *g *= inv;
            }
            glog[label] -= scale;
            let logz = max + sum.ln();
            (logz - logits[label]) as f64
        }
        TaskKind::MultiLabel => {
            let mut loss = 0f64;
            let row = &labels[node * classes..(node + 1) * classes];
            for ((g, &x), &y) in glog.iter_mut().zip(logits).zip(row) {
                let yf = y as f32;
                // stable BCE-with-logits: max(x,0) - x·y + ln(1 + e^-|x|)
                loss += (x.max(0.0) - x * yf + (-x.abs()).exp().ln_1p()) as f64;
                let sig = 1.0 / (1.0 + (-x).exp());
                *g = (sig - yf) * scale;
            }
            loss / classes as f64
        }
    }
}

/// The edge decoder of a link-prediction objective (panics on a
/// node-classification objective — callers only reach here with an
/// [`EdgeBatch`] in hand).
fn lp_decoder(objective: Objective) -> EdgeDecoder {
    match objective {
        Objective::LinkPrediction { decoder, .. } => decoder,
        Objective::NodeClassification => unreachable!("edge loss on a node-classification run"),
    }
}

/// Link-prediction loss head, shared verbatim by the serial and
/// parallel steps (so the two paths agree bit for bit): walks the
/// batch's positive then negative edges in order, scores each from the
/// final-layer embedding rows (`acts`, `dim` wide per seed), sums the
/// stable BCE-with-logits losses, and accumulates `dL/dh` into `glog`
/// (same shape as `acts`' seed rows — the existing SAGE backward
/// treats it exactly like the classification `dL/dlogits`). The
/// Hadamard decoder's `edge_w`/`edge_b` gradients land in `grads`,
/// edge-order, ready for the shared optimizer sweep. Gradients are
/// scaled by `1 / (pos + neg)` (the batch's mean edge loss); the
/// return value is the batch's **summed** per-edge losses — the
/// trainer divides by edges seen at epoch close, mirroring the
/// node-classification convention.
fn lp_edge_loss(
    decoder: EdgeDecoder,
    params: &ParamStore,
    acts: &[f32],
    dim: usize,
    eb: &EdgeBatch,
    glog: &mut [f32],
    grads: &mut BTreeMap<String, GradBuffer>,
) -> f64 {
    let num_edges = eb.num_edges();
    let gscale = 1.0 / num_edges as f32;
    let mut loss_sum = 0f64;
    let mut had = vec![0f32; if decoder == EdgeDecoder::Hadamard { dim } else { 0 }];
    for (local, y) in [(&eb.pos_local, 1.0f32), (&eb.neg_local, 0.0f32)] {
        for &(a, b) in local {
            let (a, b) = (a as usize, b as usize);
            let hu = &acts[a * dim..(a + 1) * dim];
            let hv = &acts[b * dim..(b + 1) * dim];
            let s: f32 = match decoder {
                EdgeDecoder::Dot => hu.iter().zip(hv).map(|(x, z)| x * z).sum(),
                EdgeDecoder::Hadamard => {
                    let w = params.get("edge_w");
                    let bias = params.get("edge_b")[0];
                    for ((hk, &x), &z) in had.iter_mut().zip(hu).zip(hv) {
                        *hk = x * z;
                    }
                    bias + w.iter().zip(&had).map(|(wk, hk)| wk * hk).sum::<f32>()
                }
            };
            // stable BCE-with-logits: max(s,0) - s·y + ln(1 + e^-|s|)
            loss_sum += (s.max(0.0) - s * y + (-s.abs()).exp().ln_1p()) as f64;
            let sig = 1.0 / (1.0 + (-s).exp());
            let g = (sig - y) * gscale;
            match decoder {
                EdgeDecoder::Dot => {
                    for k in 0..dim {
                        glog[a * dim + k] += g * hv[k];
                    }
                    for k in 0..dim {
                        glog[b * dim + k] += g * hu[k];
                    }
                }
                EdgeDecoder::Hadamard => {
                    let w = params.get("edge_w");
                    for k in 0..dim {
                        glog[a * dim + k] += g * w[k] * hv[k];
                    }
                    for k in 0..dim {
                        glog[b * dim + k] += g * w[k] * hu[k];
                    }
                    grads.get_mut("edge_w").expect("edge_w grads").add_row(0, g, &had);
                    grads.get_mut("edge_b").expect("edge_b grads").add_at(0, 0, g);
                }
            }
        }
    }
    loss_sum
}

/// Backpropagate one node's `dL/dv` row into its embedding tables
/// (the compose backward): position levels get the leading `d_j`
/// coordinates (Eq. 11's zero-extension), the node-specific table gets
/// `y_t · gv` per hash (indices read from the plan's node-major
/// layout), and learned importance weights get `⟨X[idx_t], gv⟩`
/// (Eq. 12/13).
fn scatter_embedding_grad(
    plan: &EmbeddingPlan,
    params: &ParamStore,
    node: usize,
    gv: &[f32],
    grads: &mut BTreeMap<String, GradBuffer>,
) {
    if let Some(pos) = &plan.position {
        for (j, table) in pos.tables.iter().enumerate() {
            let row = pos.z[j][node] as usize;
            let gb = grads.get_mut(&table.name).expect("position grads");
            gb.add_row(row, 1.0, &gv[..table.cols]);
        }
    }
    if let Some(nx) = &plan.node {
        let h = nx.h;
        let d = plan.d;
        let x = params.get(&nx.table.name);
        let y = nx.learned_weights.then(|| params.get("node_y"));
        for (t, &row) in nx.node_major[node * h..(node + 1) * h].iter().enumerate() {
            let row = row as usize;
            let w = y.map_or(1.0, |y| y[node * h + t]);
            grads.get_mut(&nx.table.name).expect("node_x grads").add_row(row, w, gv);
            if nx.learned_weights {
                let xrow = &x[row * d..(row + 1) * d];
                let dot: f32 = xrow.iter().zip(gv).map(|(a, b)| a * b).sum();
                grads.get_mut("node_y").expect("node_y grads").add_at(node, t, dot);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::spec;
    use crate::embedding::EmbeddingMethod;
    use crate::sampler::Fanout;

    fn tiny_dataset() -> Dataset {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 400;
        s.communities = 20;
        s.d = 16;
        Dataset::generate(&s)
    }

    #[test]
    fn dhe_plans_are_rejected() {
        let ds = tiny_dataset();
        let method = EmbeddingMethod::Dhe { encoding_dim: 8, hidden: 16, layers: 1 };
        let plan = EmbeddingPlan::build(ds.graph.num_nodes(), 16, &method, None, 0);
        let err = MinibatchTrainer::new(&ds, &plan, SamplerConfig::default(), Default::default());
        assert!(err.is_err());
        assert!(train_full_batch(&ds, &plan, &MinibatchOptions::default(), 1).is_err());
    }

    #[test]
    fn host_params_include_head_tables() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            1,
        );
        let p = init_host_params(&plan, ds.spec.classes, 1, 64, 7);
        assert_eq!(p.shape("head_w_self"), &[16, ds.spec.classes]);
        assert_eq!(p.shape("head_w_neigh"), &[16, ds.spec.classes]);
        assert!(p.get("head_b").iter().all(|&b| b == 0.0));
        // deterministic per seed
        let q = init_host_params(&plan, ds.spec.classes, 1, 64, 7);
        assert_eq!(p.get("head_w_self"), q.get("head_w_self"));
        // a 2-layer head gets per-layer names and a hidden mid width
        let deep = init_host_params(&plan, ds.spec.classes, 2, 24, 7);
        assert_eq!(deep.shape("head0_w_self"), &[16, 24]);
        assert_eq!(deep.shape("head1_w_self"), &[24, ds.spec.classes]);
        assert_eq!(deep.shape("head1_b"), &[1, ds.spec.classes]);
        // layer 0's draws come first from the same stream, so they
        // cannot depend on the deeper layers' existence when the input
        // dim matches
        assert_eq!(layer_dims(16, ds.spec.classes, 24, 2, 0), (16, 24));
        assert_eq!(layer_dims(16, ds.spec.classes, 24, 2, 1), (24, ds.spec.classes));
    }

    #[test]
    fn single_epoch_runs_and_reports_finite_loss() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            1,
        );
        let cfg = SamplerConfig { batch_size: 64, fanouts: Fanout::Max(4).into(), shuffle: true };
        let opts = MinibatchOptions { epochs: 2, ..Default::default() };
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
        assert_eq!(tr.layers(), 1);
        let out = tr.train().unwrap();
        assert_eq!(out.losses.len(), 2);
        assert!(out.losses.iter().all(|l| l.is_finite()));
        assert!(out.peak_compose_rows < ds.graph.num_nodes());
        assert!((0.0..=1.0).contains(&out.test_metric));
        assert!(out.row().contains("peak_rows"));
    }

    #[test]
    fn two_layer_head_trains_with_finite_loss() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            3,
        );
        let cfg = SamplerConfig {
            batch_size: 64,
            fanouts: Fanouts::parse("4,3").unwrap(),
            shuffle: true,
        };
        let opts = MinibatchOptions { epochs: 2, hidden: 16, ..Default::default() };
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
        assert_eq!(tr.layers(), 2);
        let out = tr.train().unwrap();
        assert!(out.losses.iter().all(|l| l.is_finite()));
        assert!(out.peak_compose_rows < ds.graph.num_nodes());
        assert!((0.0..=1.0).contains(&out.test_metric));
    }

    #[test]
    fn objective_parse_display_roundtrip() {
        assert_eq!(Objective::parse("nodeclass").unwrap(), Objective::NodeClassification);
        assert_eq!(Objective::parse("nc").unwrap(), Objective::NodeClassification);
        let lp = Objective::parse("linkpred").unwrap().with_neg_per_pos(3);
        assert_eq!(lp, Objective::LinkPrediction { decoder: EdgeDecoder::Dot, neg_per_pos: 3 });
        assert_eq!(lp.to_string(), "linkpred(dot,neg=3)");
        let had = Objective::parse("linkpred-hadamard").unwrap();
        assert_eq!(
            had,
            Objective::LinkPrediction { decoder: EdgeDecoder::Hadamard, neg_per_pos: 1 }
        );
        assert_eq!(had.to_string(), "linkpred(hadamard,neg=1)");
        assert!(Objective::parse("??").is_err());
        assert!(!Objective::NodeClassification.is_link());
        assert!(lp.is_link());
        assert_eq!(Objective::NodeClassification.to_string(), "nodeclass");
    }

    #[test]
    fn link_prediction_trains_and_reports_auc_and_hits() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            1,
        );
        let cfg = SamplerConfig { batch_size: 64, fanouts: Fanout::Max(4).into(), shuffle: true };
        let opts = MinibatchOptions {
            epochs: 2,
            hidden: 16,
            objective: Objective::LinkPrediction {
                decoder: EdgeDecoder::Dot,
                neg_per_pos: 1,
            },
            ..Default::default()
        };
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
        let out = tr.train().unwrap();
        assert_eq!(out.losses.len(), 2);
        assert!(out.losses.iter().all(|l| l.is_finite()));
        assert!(out.peak_compose_rows < ds.graph.num_nodes());
        assert!((0.0..=1.0).contains(&out.val_metric));
        assert!((0.0..=1.0).contains(&out.test_metric));
        let hits = out.test_hits.expect("link prediction reports hits@k");
        assert!((0.0..=1.0).contains(&hits));
        assert!(out.val_hits.is_some());
    }

    #[test]
    fn hadamard_decoder_trains_with_edge_params() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            5,
        );
        let cfg = SamplerConfig { batch_size: 64, fanouts: Fanout::Max(4).into(), shuffle: true };
        let opts = MinibatchOptions {
            epochs: 1,
            hidden: 16,
            objective: Objective::LinkPrediction {
                decoder: EdgeDecoder::Hadamard,
                neg_per_pos: 2,
            },
            ..Default::default()
        };
        let mut tr = MinibatchTrainer::new(&ds, &plan, cfg, opts).unwrap();
        assert_eq!(tr.params().shape("edge_w"), &[1, 16]);
        assert_eq!(tr.params().shape("edge_b"), &[1, 1]);
        let out = tr.train().unwrap();
        assert!(out.losses.iter().all(|l| l.is_finite()));
        assert!((0.0..=1.0).contains(&out.test_metric));
    }

    #[test]
    fn link_prediction_requires_hidden_width() {
        let ds = tiny_dataset();
        let plan = EmbeddingPlan::build(
            ds.graph.num_nodes(),
            16,
            &EmbeddingMethod::HashEmb { buckets: 32, h: 2 },
            None,
            1,
        );
        let opts = MinibatchOptions {
            hidden: 0,
            objective: Objective::LinkPrediction { decoder: EdgeDecoder::Dot, neg_per_pos: 1 },
            ..Default::default()
        };
        assert!(MinibatchTrainer::new(&ds, &plan, SamplerConfig::default(), opts).is_err());
        // and the full-batch oracle refuses the objective outright
        let lp_opts = MinibatchOptions {
            hidden: 16,
            objective: Objective::LinkPrediction { decoder: EdgeDecoder::Dot, neg_per_pos: 1 },
            ..Default::default()
        };
        assert!(train_full_batch(&ds, &plan, &lp_opts, 1).is_err());
    }
}
