//! Host-side first-order optimizers for minibatch training: sparse
//! gradient accumulation ([`GradBuffer`]) and SGD / lazy-sparse Adam
//! updates ([`Optimizer`]).
//!
//! Minibatch steps touch only the parameter rows a sampled block reaches
//! (that is the whole point of composing subsets), so the optimizer
//! works in touched-row space: gradients accumulate into a dense
//! table-shaped buffer but only touched rows are read, updated and
//! re-zeroed — `O(params)` memory, `O(touched × d)` work per step.
//! Adam moments follow the standard lazy/sparse convention: rows that a
//! step does not touch keep their moments and parameters unchanged, so
//! the fanout = ∞ oracle configuration (which touches exactly the rows
//! full-batch training touches) reproduces full-batch Adam bit for bit.
//!
//! **Parallelism.** Both halves of a step parallelize without giving up
//! a single bit: accumulation via [`GradBuffer::sharded_accumulate`]
//! (contiguous row-range shards own disjoint slices — no locks; the
//! per-element add order is whatever order the caller's scan adds in,
//! independent of shard or thread count) and the update via
//! [`Optimizer`]'s `parallel` flag (touched rows are unique, so row
//! updates are independent and reorder freely).

use rayon::prelude::*;
use std::collections::HashMap;
use std::ops::Range;

/// Which update rule the host-side trainers apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Plain SGD: `w -= lr · g`.
    Sgd,
    /// Adam (Kingma & Ba 2015) with bias correction and lazy sparse
    /// moments (untouched rows are left untouched).
    Adam,
}

impl OptimizerKind {
    /// CLI tag (`sgd` / `adam`).
    pub fn as_str(self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
        }
    }

    /// Parse a CLI tag.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "sgd" => Ok(OptimizerKind::Sgd),
            "adam" => Ok(OptimizerKind::Adam),
            other => Err(format!("unknown optimizer '{other}' (sgd|adam)")),
        }
    }
}

/// Dense table-shaped gradient accumulator with touched-row tracking.
///
/// `add_row` sums into a row (marking it touched); `clear` re-zeroes
/// only the touched rows, so a long training run never pays `O(params)`
/// per step. Touch order is preserved — together with the deterministic
/// sampler this keeps whole runs bit-identical across thread counts.
#[derive(Debug, Clone)]
pub struct GradBuffer {
    grad: Vec<f32>,
    cols: usize,
    touched: Vec<u32>,
    is_touched: Vec<bool>,
}

impl GradBuffer {
    /// Zeroed accumulator for a `rows × cols` table.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(cols >= 1, "cols must be >= 1");
        GradBuffer {
            grad: vec![0.0; rows * cols],
            cols,
            touched: Vec::new(),
            is_touched: vec![false; rows],
        }
    }

    /// Columns per row.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Rows touched since the last [`clear`](GradBuffer::clear), in
    /// first-touch order.
    pub fn touched_rows(&self) -> &[u32] {
        &self.touched
    }

    /// Accumulated gradient of one row.
    pub fn row(&self, row: usize) -> &[f32] {
        &self.grad[row * self.cols..(row + 1) * self.cols]
    }

    #[inline]
    fn touch(&mut self, row: usize) {
        if !self.is_touched[row] {
            self.is_touched[row] = true;
            self.touched.push(row as u32);
        }
    }

    /// `grad[row][..src.len()] += scale · src`. A `src` shorter than the
    /// row accumulates into the leading columns only (the zero-extension
    /// convention position tables use, Eq. 11).
    #[inline]
    pub fn add_row(&mut self, row: usize, scale: f32, src: &[f32]) {
        debug_assert!(src.len() <= self.cols, "src wider than the table row");
        self.touch(row);
        let base = row * self.cols;
        let dst = &mut self.grad[base..base + src.len()];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += scale * s;
        }
    }

    /// `grad[row][col] += v` (importance-weight gradients).
    #[inline]
    pub fn add_at(&mut self, row: usize, col: usize, v: f32) {
        debug_assert!(col < self.cols);
        self.touch(row);
        self.grad[row * self.cols + col] += v;
    }

    /// Zero the touched rows and reset the touch set.
    pub fn clear(&mut self) {
        for &r in &self.touched {
            let base = r as usize * self.cols;
            self.grad[base..base + self.cols].fill(0.0);
            self.is_touched[r as usize] = false;
        }
        self.touched.clear();
    }

    /// Lock-free parallel accumulation: split the buffer into at most
    /// `max_shards` contiguous row-range shards, run `accumulate` on
    /// every shard on the rayon pool, then merge the shards' touch
    /// lists back in fixed shard order.
    ///
    /// Each destination row belongs to exactly one shard, so shards own
    /// disjoint `grad` slices and no synchronization (and no merge of
    /// float state) is needed. `accumulate` must scan its workload in
    /// the same order for every shard and add only rows the shard
    /// [`contains`](GradShard::contains) — then each element's
    /// accumulation order is the scan order, exactly as if the same
    /// scan had run serially, so the result is **bit-identical** to
    /// serial accumulation at any shard or thread count (pinned by
    /// `tests/parallel_train.rs`). The decomposition depends only on
    /// `(rows, max_shards)`, never on the pool size.
    pub fn sharded_accumulate<F>(&mut self, max_shards: usize, accumulate: F)
    where
        F: Fn(&mut GradShard<'_>) + Sync,
    {
        let rows = self.is_touched.len();
        if rows == 0 {
            return;
        }
        let num = max_shards.clamp(1, rows);
        let per = rows.div_ceil(num);
        let mut shards: Vec<GradShard<'_>> = Vec::with_capacity(num);
        let mut grad_rest: &mut [f32] = &mut self.grad;
        let mut touch_rest: &mut [bool] = &mut self.is_touched;
        let mut row0 = 0usize;
        while row0 < rows {
            let take = per.min(rows - row0);
            let (g, g_rest) = std::mem::take(&mut grad_rest).split_at_mut(take * self.cols);
            let (t, t_rest) = std::mem::take(&mut touch_rest).split_at_mut(take);
            grad_rest = g_rest;
            touch_rest = t_rest;
            shards.push(GradShard {
                row0,
                cols: self.cols,
                grad: g,
                is_touched: t,
                touched: Vec::new(),
            });
            row0 += take;
        }
        shards.par_iter_mut().for_each(&accumulate);
        for sh in shards {
            self.touched.extend_from_slice(&sh.touched);
        }
    }
}

/// One contiguous row-range of a [`GradBuffer`], handed to
/// [`GradBuffer::sharded_accumulate`] workers. Mirrors the buffer's
/// `add_row`/`add_at` API on global row ids; rows outside the shard are
/// rejected (debug assert), which is what makes the shards lock-free.
pub struct GradShard<'a> {
    row0: usize,
    cols: usize,
    grad: &'a mut [f32],
    is_touched: &'a mut [bool],
    touched: Vec<u32>,
}

impl GradShard<'_> {
    /// The global row range this shard owns.
    pub fn rows(&self) -> Range<usize> {
        self.row0..self.row0 + self.is_touched.len()
    }

    /// Does this shard own `row`?
    #[inline]
    pub fn contains(&self, row: usize) -> bool {
        row >= self.row0 && row < self.row0 + self.is_touched.len()
    }

    #[inline]
    fn touch(&mut self, local: usize) {
        if !self.is_touched[local] {
            self.is_touched[local] = true;
            self.touched.push((self.row0 + local) as u32);
        }
    }

    /// `grad[row][..src.len()] += scale · src` — the shard-local
    /// counterpart of [`GradBuffer::add_row`]; `row` is global and must
    /// be in [`rows`](GradShard::rows).
    #[inline]
    pub fn add_row(&mut self, row: usize, scale: f32, src: &[f32]) {
        debug_assert!(self.contains(row), "row {row} outside shard {:?}", self.rows());
        debug_assert!(src.len() <= self.cols, "src wider than the table row");
        let local = row - self.row0;
        self.touch(local);
        let base = local * self.cols;
        let dst = &mut self.grad[base..base + src.len()];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += scale * s;
        }
    }

    /// `grad[row][col] += v` — the shard-local counterpart of
    /// [`GradBuffer::add_at`]; `row` is global.
    #[inline]
    pub fn add_at(&mut self, row: usize, col: usize, v: f32) {
        debug_assert!(self.contains(row), "row {row} outside shard {:?}", self.rows());
        debug_assert!(col < self.cols);
        let local = row - self.row0;
        self.touch(local);
        self.grad[local * self.cols + col] += v;
    }
}

/// SGD / Adam over named parameter tables, applying updates only to the
/// rows a [`GradBuffer`] marks touched.
#[derive(Debug)]
pub struct Optimizer {
    kind: OptimizerKind,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    step: u64,
    /// Run [`apply`](Optimizer::apply) over touched rows on the rayon
    /// pool when a step touches enough of them. Touched rows are unique
    /// and row updates are independent, so the parallel path is
    /// bit-identical to serial at any thread count. Off by default (the
    /// serial oracle); the pipelined trainer switches it on.
    pub parallel: bool,
    /// Lazily allocated per-table (first moment, second moment) state.
    moments: HashMap<String, (Vec<f32>, Vec<f32>)>,
}

/// Fewest touched rows for which the parallel apply path is worth the
/// rayon dispatch; a fixed constant so the serial/parallel choice never
/// depends on the pool size.
const PARALLEL_APPLY_MIN_ROWS: usize = 128;

/// Raw table pointer smuggled into a rayon closure. Safe to share
/// because every worker derives its row slice from a **unique** touched
/// row id — slices are disjoint by construction.
#[derive(Clone, Copy)]
struct TablePtr(*mut f32);
unsafe impl Send for TablePtr {}
unsafe impl Sync for TablePtr {}

impl TablePtr {
    /// The `cols`-wide row slice starting at `base`.
    ///
    /// # Safety
    /// `base + cols` must be within the table allocation, and no other
    /// live reference may overlap the row (guaranteed when `base` is
    /// derived from unique touched row ids).
    #[inline]
    unsafe fn row_mut<'a>(self, base: usize, cols: usize) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.0.add(base), cols)
    }
}

impl Optimizer {
    /// Optimizer with standard Adam hyperparameters
    /// (`beta1 = 0.9`, `beta2 = 0.999`, `eps = 1e-8`).
    pub fn new(kind: OptimizerKind, lr: f32) -> Self {
        assert!(lr > 0.0, "lr must be positive");
        Optimizer {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            step: 0,
            parallel: false,
            moments: HashMap::new(),
        }
    }

    /// Advance the (bias-correction) step counter; call once per
    /// minibatch step, before [`apply`](Optimizer::apply).
    pub fn begin_step(&mut self) {
        self.step += 1;
    }

    /// Steps taken so far.
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// The update rule this optimizer applies.
    pub fn kind(&self) -> OptimizerKind {
        self.kind
    }

    /// The learning rate.
    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Overwrite the step counter — checkpoint resume only. Adam's
    /// bias correction is a pure function of the step count, so
    /// restoring it (with the moments) makes the next `apply`
    /// bit-identical to the uninterrupted run's.
    pub fn set_step_count(&mut self, step: u64) {
        self.step = step;
    }

    /// The lazily allocated Adam moment tables, name-sorted — the
    /// checkpoint writer's deterministic section order. Empty for SGD
    /// (and before the first Adam `apply`); a table absent here is
    /// exactly equivalent to all-zero moments, because the lazy
    /// allocation in [`apply`](Optimizer::apply) zero-initializes.
    pub fn moment_tables(&self) -> Vec<(&str, &[f32], &[f32])> {
        let mut tables: Vec<(&str, &[f32], &[f32])> = self
            .moments
            .iter()
            .map(|(name, (m, v))| (name.as_str(), m.as_slice(), v.as_slice()))
            .collect();
        tables.sort_by_key(|t| t.0);
        tables
    }

    /// Install restored moment state for one table — checkpoint resume
    /// only. `m` and `v` must have the table's full element count (the
    /// next `apply` indexes them by row).
    pub fn restore_moments(&mut self, name: &str, m: Vec<f32>, v: Vec<f32>) {
        assert_eq!(m.len(), v.len(), "moment tables for '{name}' disagree on length");
        self.moments.insert(name.to_string(), (m, v));
    }

    /// Apply `gb`'s accumulated gradients to the row-major table `data`.
    /// Only touched rows are updated; `gb` is not cleared here. With
    /// [`parallel`](Optimizer::parallel) set and enough touched rows,
    /// the per-row updates run on the rayon pool — same bits, since no
    /// two touched rows alias.
    pub fn apply(&mut self, name: &str, data: &mut [f32], gb: &GradBuffer) {
        let cols = gb.cols();
        let touched = gb.touched_rows();
        let par = self.parallel && touched.len() >= PARALLEL_APPLY_MIN_ROWS;
        match self.kind {
            OptimizerKind::Sgd => {
                let lr = self.lr;
                if par {
                    let table = TablePtr(data.as_mut_ptr());
                    touched.par_iter().for_each(|&r| {
                        let base = r as usize * cols;
                        // SAFETY: touched rows are unique, so each
                        // worker's row slice is disjoint and in bounds
                        // (GradBuffer and table share the row count).
                        let dst = unsafe { table.row_mut(base, cols) };
                        for (w, g) in dst.iter_mut().zip(gb.row(r as usize)) {
                            *w -= lr * g;
                        }
                    });
                } else {
                    for &r in touched {
                        let base = r as usize * cols;
                        let dst = &mut data[base..base + cols];
                        for (w, g) in dst.iter_mut().zip(gb.row(r as usize)) {
                            *w -= lr * g;
                        }
                    }
                }
            }
            OptimizerKind::Adam => {
                assert!(self.step > 0, "begin_step before apply");
                let (beta1, beta2, eps) = (self.beta1, self.beta2, self.eps);
                let (m, v) = self
                    .moments
                    .entry(name.to_string())
                    .or_insert_with(|| (vec![0.0; data.len()], vec![0.0; data.len()]));
                let t = self.step.min(i32::MAX as u64) as i32;
                let bc1 = 1.0 - beta1.powi(t);
                let bc2 = 1.0 - beta2.powi(t);
                let alpha = self.lr * bc2.sqrt() / bc1;
                if par {
                    let table = TablePtr(data.as_mut_ptr());
                    let m_ptr = TablePtr(m.as_mut_ptr());
                    let v_ptr = TablePtr(v.as_mut_ptr());
                    touched.par_iter().for_each(|&r| {
                        let base = r as usize * cols;
                        // SAFETY: touched rows are unique, so the data
                        // and moment row slices of different workers
                        // never overlap; all three buffers share the
                        // table's length.
                        let (dst, mr, vr) = unsafe {
                            (
                                table.row_mut(base, cols),
                                m_ptr.row_mut(base, cols),
                                v_ptr.row_mut(base, cols),
                            )
                        };
                        for (((w, mi), vi), &g) in
                            dst.iter_mut().zip(mr).zip(vr).zip(gb.row(r as usize))
                        {
                            *mi = beta1 * *mi + (1.0 - beta1) * g;
                            *vi = beta2 * *vi + (1.0 - beta2) * g * g;
                            *w -= alpha * *mi / (vi.sqrt() + eps);
                        }
                    });
                } else {
                    for &r in touched {
                        let base = r as usize * cols;
                        for (i, &g) in gb.row(r as usize).iter().enumerate() {
                            let idx = base + i;
                            m[idx] = beta1 * m[idx] + (1.0 - beta1) * g;
                            v[idx] = beta2 * v[idx] + (1.0 - beta2) * g * g;
                            data[idx] -= alpha * m[idx] / (v[idx].sqrt() + eps);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_buffer_accumulates_and_clears_touched_only() {
        let mut gb = GradBuffer::new(4, 3);
        gb.add_row(2, 2.0, &[1.0, 2.0, 3.0]);
        gb.add_row(2, 1.0, &[1.0, 0.0, 0.0]);
        gb.add_at(0, 1, 5.0);
        assert_eq!(gb.touched_rows(), &[2, 0]);
        assert_eq!(gb.row(2), &[3.0, 4.0, 6.0]);
        assert_eq!(gb.row(0), &[0.0, 5.0, 0.0]);
        gb.clear();
        assert!(gb.touched_rows().is_empty());
        assert_eq!(gb.row(2), &[0.0; 3]);
    }

    #[test]
    fn short_src_hits_leading_columns_only() {
        let mut gb = GradBuffer::new(2, 4);
        gb.add_row(1, 1.0, &[7.0, 8.0]);
        assert_eq!(gb.row(1), &[7.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn sgd_updates_only_touched_rows() {
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 0.5);
        let mut data = vec![1.0f32; 6]; // 3 rows × 2 cols
        let mut gb = GradBuffer::new(3, 2);
        gb.add_row(1, 1.0, &[2.0, 4.0]);
        opt.begin_step();
        opt.apply("t", &mut data, &gb);
        assert_eq!(data, vec![1.0, 1.0, 0.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn adam_leaves_untouched_rows_and_their_moments_alone() {
        let mut opt = Optimizer::new(OptimizerKind::Adam, 0.1);
        let mut data = vec![1.0f32; 4]; // 2 rows × 2 cols
        let mut gb = GradBuffer::new(2, 2);
        for _ in 0..3 {
            gb.add_row(0, 1.0, &[1.0, -1.0]);
            opt.begin_step();
            opt.apply("t", &mut data, &gb);
            gb.clear();
        }
        // row 0 moved toward the gradient direction; row 1 untouched
        assert!(data[0] < 1.0 && data[1] > 1.0);
        assert_eq!(&data[2..], &[1.0, 1.0]);
        // first Adam step moves by ~lr regardless of gradient magnitude
        let mut opt2 = Optimizer::new(OptimizerKind::Adam, 0.1);
        let mut w = vec![0.0f32; 2];
        let mut gb2 = GradBuffer::new(1, 2);
        gb2.add_row(0, 1.0, &[100.0, 1e-3]);
        opt2.begin_step();
        opt2.apply("w", &mut w, &gb2);
        assert!((w[0] + 0.1).abs() < 1e-3, "w[0] = {}", w[0]);
    }

    #[test]
    fn sharded_accumulate_matches_serial_accumulation_exactly() {
        let (rows, cols) = (37, 5);
        // synthetic scatter workload: every op hits a pseudo-random row
        let ops: Vec<(usize, f32, Vec<f32>)> = (0..200)
            .map(|k| {
                let row = (k * 17 + 3) % rows;
                let scale = 0.25 + (k % 7) as f32 * 0.125;
                let src: Vec<f32> = (0..cols).map(|c| (k * cols + c) as f32 * 0.01 - 1.0).collect();
                (row, scale, src)
            })
            .collect();
        let mut serial = GradBuffer::new(rows, cols);
        for (row, scale, src) in &ops {
            serial.add_row(*row, *scale, src);
        }
        for shards in [1usize, 3, 8, 64] {
            let mut sharded = GradBuffer::new(rows, cols);
            sharded.sharded_accumulate(shards, |sh| {
                for (row, scale, src) in &ops {
                    if sh.contains(*row) {
                        sh.add_row(*row, *scale, src);
                    }
                }
            });
            for row in 0..rows {
                assert_eq!(serial.row(row), sharded.row(row), "row {row}, {shards} shards");
            }
            let mut a: Vec<u32> = serial.touched_rows().to_vec();
            let mut b: Vec<u32> = sharded.touched_rows().to_vec();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "{shards} shards");
        }
    }

    #[test]
    fn shard_rows_partition_the_buffer() {
        let mut gb = GradBuffer::new(10, 2);
        let mut seen: Vec<usize> = Vec::new();
        let ranges = std::sync::Mutex::new(&mut seen);
        gb.sharded_accumulate(3, |sh| {
            ranges.lock().unwrap().extend(sh.rows());
            assert!(sh.contains(sh.rows().start));
            assert!(!sh.contains(sh.rows().end));
        });
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_apply_is_bit_identical_to_serial() {
        let (rows, cols) = (PARALLEL_APPLY_MIN_ROWS + 70, 3);
        for kind in [OptimizerKind::Sgd, OptimizerKind::Adam] {
            let mut serial = Optimizer::new(kind, 0.05);
            let mut parallel = Optimizer::new(kind, 0.05);
            parallel.parallel = true;
            let init: Vec<f32> = (0..rows * cols).map(|i| (i as f32 * 0.37).sin()).collect();
            let (mut ws, mut wp) = (init.clone(), init);
            let mut gb = GradBuffer::new(rows, cols);
            for step in 0..3 {
                for r in 0..rows {
                    let g: Vec<f32> =
                        (0..cols).map(|c| ((r + c + step) as f32 * 0.11).cos()).collect();
                    gb.add_row(r, 1.0, &g);
                }
                serial.begin_step();
                parallel.begin_step();
                serial.apply("t", &mut ws, &gb);
                parallel.apply("t", &mut wp, &gb);
                gb.clear();
                assert_eq!(ws, wp, "{} step {step}", kind.as_str());
            }
        }
    }

    #[test]
    fn optimizer_kind_parse_roundtrip() {
        assert_eq!(OptimizerKind::parse("sgd").unwrap(), OptimizerKind::Sgd);
        assert_eq!(OptimizerKind::parse("adam").unwrap().as_str(), "adam");
        assert!(OptimizerKind::parse("lbfgs").is_err());
    }
}
