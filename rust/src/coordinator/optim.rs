//! Host-side first-order optimizers for minibatch training: sparse
//! gradient accumulation ([`GradBuffer`]) and SGD / lazy-sparse Adam
//! updates ([`Optimizer`]).
//!
//! Minibatch steps touch only the parameter rows a sampled block reaches
//! (that is the whole point of composing subsets), so the optimizer
//! works in touched-row space: gradients accumulate into a dense
//! table-shaped buffer but only touched rows are read, updated and
//! re-zeroed — `O(params)` memory, `O(touched × d)` work per step.
//! Adam moments follow the standard lazy/sparse convention: rows that a
//! step does not touch keep their moments and parameters unchanged, so
//! the fanout = ∞ oracle configuration (which touches exactly the rows
//! full-batch training touches) reproduces full-batch Adam bit for bit.

use std::collections::HashMap;

/// Which update rule the host-side trainers apply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    /// Plain SGD: `w -= lr · g`.
    Sgd,
    /// Adam (Kingma & Ba 2015) with bias correction and lazy sparse
    /// moments (untouched rows are left untouched).
    Adam,
}

impl OptimizerKind {
    /// CLI tag (`sgd` / `adam`).
    pub fn as_str(self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
        }
    }

    /// Parse a CLI tag.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "sgd" => Ok(OptimizerKind::Sgd),
            "adam" => Ok(OptimizerKind::Adam),
            other => Err(format!("unknown optimizer '{other}' (sgd|adam)")),
        }
    }
}

/// Dense table-shaped gradient accumulator with touched-row tracking.
///
/// `add_row` sums into a row (marking it touched); `clear` re-zeroes
/// only the touched rows, so a long training run never pays `O(params)`
/// per step. Touch order is preserved — together with the deterministic
/// sampler this keeps whole runs bit-identical across thread counts.
#[derive(Debug, Clone)]
pub struct GradBuffer {
    grad: Vec<f32>,
    cols: usize,
    touched: Vec<u32>,
    is_touched: Vec<bool>,
}

impl GradBuffer {
    /// Zeroed accumulator for a `rows × cols` table.
    pub fn new(rows: usize, cols: usize) -> Self {
        assert!(cols >= 1, "cols must be >= 1");
        GradBuffer {
            grad: vec![0.0; rows * cols],
            cols,
            touched: Vec::new(),
            is_touched: vec![false; rows],
        }
    }

    /// Columns per row.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Rows touched since the last [`clear`](GradBuffer::clear), in
    /// first-touch order.
    pub fn touched_rows(&self) -> &[u32] {
        &self.touched
    }

    /// Accumulated gradient of one row.
    pub fn row(&self, row: usize) -> &[f32] {
        &self.grad[row * self.cols..(row + 1) * self.cols]
    }

    #[inline]
    fn touch(&mut self, row: usize) {
        if !self.is_touched[row] {
            self.is_touched[row] = true;
            self.touched.push(row as u32);
        }
    }

    /// `grad[row][..src.len()] += scale · src`. A `src` shorter than the
    /// row accumulates into the leading columns only (the zero-extension
    /// convention position tables use, Eq. 11).
    #[inline]
    pub fn add_row(&mut self, row: usize, scale: f32, src: &[f32]) {
        debug_assert!(src.len() <= self.cols, "src wider than the table row");
        self.touch(row);
        let base = row * self.cols;
        let dst = &mut self.grad[base..base + src.len()];
        for (o, s) in dst.iter_mut().zip(src) {
            *o += scale * s;
        }
    }

    /// `grad[row][col] += v` (importance-weight gradients).
    #[inline]
    pub fn add_at(&mut self, row: usize, col: usize, v: f32) {
        debug_assert!(col < self.cols);
        self.touch(row);
        self.grad[row * self.cols + col] += v;
    }

    /// Zero the touched rows and reset the touch set.
    pub fn clear(&mut self) {
        for &r in &self.touched {
            let base = r as usize * self.cols;
            self.grad[base..base + self.cols].fill(0.0);
            self.is_touched[r as usize] = false;
        }
        self.touched.clear();
    }
}

/// SGD / Adam over named parameter tables, applying updates only to the
/// rows a [`GradBuffer`] marks touched.
#[derive(Debug)]
pub struct Optimizer {
    kind: OptimizerKind,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    step: u64,
    /// Lazily allocated per-table (first moment, second moment) state.
    moments: HashMap<String, (Vec<f32>, Vec<f32>)>,
}

impl Optimizer {
    /// Optimizer with standard Adam hyperparameters
    /// (`beta1 = 0.9`, `beta2 = 0.999`, `eps = 1e-8`).
    pub fn new(kind: OptimizerKind, lr: f32) -> Self {
        assert!(lr > 0.0, "lr must be positive");
        Optimizer {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            step: 0,
            moments: HashMap::new(),
        }
    }

    /// Advance the (bias-correction) step counter; call once per
    /// minibatch step, before [`apply`](Optimizer::apply).
    pub fn begin_step(&mut self) {
        self.step += 1;
    }

    /// Steps taken so far.
    pub fn step_count(&self) -> u64 {
        self.step
    }

    /// Apply `gb`'s accumulated gradients to the row-major table `data`.
    /// Only touched rows are updated; `gb` is not cleared here.
    pub fn apply(&mut self, name: &str, data: &mut [f32], gb: &GradBuffer) {
        let cols = gb.cols();
        match self.kind {
            OptimizerKind::Sgd => {
                for &r in gb.touched_rows() {
                    let base = r as usize * cols;
                    let dst = &mut data[base..base + cols];
                    for (w, g) in dst.iter_mut().zip(gb.row(r as usize)) {
                        *w -= self.lr * g;
                    }
                }
            }
            OptimizerKind::Adam => {
                assert!(self.step > 0, "begin_step before apply");
                let (m, v) = self
                    .moments
                    .entry(name.to_string())
                    .or_insert_with(|| (vec![0.0; data.len()], vec![0.0; data.len()]));
                let t = self.step.min(i32::MAX as u64) as i32;
                let bc1 = 1.0 - self.beta1.powi(t);
                let bc2 = 1.0 - self.beta2.powi(t);
                let alpha = self.lr * bc2.sqrt() / bc1;
                for &r in gb.touched_rows() {
                    let base = r as usize * cols;
                    for (i, &g) in gb.row(r as usize).iter().enumerate() {
                        let idx = base + i;
                        m[idx] = self.beta1 * m[idx] + (1.0 - self.beta1) * g;
                        v[idx] = self.beta2 * v[idx] + (1.0 - self.beta2) * g * g;
                        data[idx] -= alpha * m[idx] / (v[idx].sqrt() + self.eps);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grad_buffer_accumulates_and_clears_touched_only() {
        let mut gb = GradBuffer::new(4, 3);
        gb.add_row(2, 2.0, &[1.0, 2.0, 3.0]);
        gb.add_row(2, 1.0, &[1.0, 0.0, 0.0]);
        gb.add_at(0, 1, 5.0);
        assert_eq!(gb.touched_rows(), &[2, 0]);
        assert_eq!(gb.row(2), &[3.0, 4.0, 6.0]);
        assert_eq!(gb.row(0), &[0.0, 5.0, 0.0]);
        gb.clear();
        assert!(gb.touched_rows().is_empty());
        assert_eq!(gb.row(2), &[0.0; 3]);
    }

    #[test]
    fn short_src_hits_leading_columns_only() {
        let mut gb = GradBuffer::new(2, 4);
        gb.add_row(1, 1.0, &[7.0, 8.0]);
        assert_eq!(gb.row(1), &[7.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn sgd_updates_only_touched_rows() {
        let mut opt = Optimizer::new(OptimizerKind::Sgd, 0.5);
        let mut data = vec![1.0f32; 6]; // 3 rows × 2 cols
        let mut gb = GradBuffer::new(3, 2);
        gb.add_row(1, 1.0, &[2.0, 4.0]);
        opt.begin_step();
        opt.apply("t", &mut data, &gb);
        assert_eq!(data, vec![1.0, 1.0, 0.0, -1.0, 1.0, 1.0]);
    }

    #[test]
    fn adam_leaves_untouched_rows_and_their_moments_alone() {
        let mut opt = Optimizer::new(OptimizerKind::Adam, 0.1);
        let mut data = vec![1.0f32; 4]; // 2 rows × 2 cols
        let mut gb = GradBuffer::new(2, 2);
        for _ in 0..3 {
            gb.add_row(0, 1.0, &[1.0, -1.0]);
            opt.begin_step();
            opt.apply("t", &mut data, &gb);
            gb.clear();
        }
        // row 0 moved toward the gradient direction; row 1 untouched
        assert!(data[0] < 1.0 && data[1] > 1.0);
        assert_eq!(&data[2..], &[1.0, 1.0]);
        // first Adam step moves by ~lr regardless of gradient magnitude
        let mut opt2 = Optimizer::new(OptimizerKind::Adam, 0.1);
        let mut w = vec![0.0f32; 2];
        let mut gb2 = GradBuffer::new(1, 2);
        gb2.add_row(0, 1.0, &[100.0, 1e-3]);
        opt2.begin_step();
        opt2.apply("w", &mut w, &gb2);
        assert!((w[0] + 0.1).abs() < 1e-3, "w[0] = {}", w[0]);
    }

    #[test]
    fn optimizer_kind_parse_roundtrip() {
        assert_eq!(OptimizerKind::parse("sgd").unwrap(), OptimizerKind::Sgd);
        assert_eq!(OptimizerKind::parse("adam").unwrap().as_str(), "adam");
        assert!(OptimizerKind::parse("lbfgs").is_err());
    }
}
