//! Static tensor construction: the graph/index arrays each model's
//! artifact consumes (named slots of the ABI).
//!
//! * all models + embedding: `z` (L×n), `node_idx` (h×n), `dhe_enc`
//! * GCN: `adj_idx`/`adj_w` — adjacency rows padded to K = max_deg + 1
//!   with GCN renormalization coefficients and the self loop in the last
//!   occupied slot (weight-0 padding rows point at the node itself).
//! * SAGE: `src`/`dst` COO + `inv_deg`.
//! * GAT: `src`/`dst` COO (self edge handled analytically in the HLO).

use crate::config::ModelKind;
use crate::data::Dataset;
use crate::embedding::EmbeddingPlan;
use crate::runtime::HostTensor;

/// Build all named static tensors for (dataset, model, plan).
pub fn build_statics(
    ds: &Dataset,
    model: ModelKind,
    plan: &EmbeddingPlan,
) -> Vec<(String, HostTensor)> {
    let mut out = Vec::new();
    let n = ds.graph.num_nodes();
    // embedding statics (ABI order: z, node_idx, dhe_enc)
    if let Some(pos) = &plan.position {
        let z = plan.z_indices_i32().unwrap();
        out.push(("z".to_string(), HostTensor::I32(z, vec![pos.z.len(), n])));
    }
    if let Some(node) = &plan.node {
        // hash-major h × n, transposed from the plan's node-major
        // layout at export time (the ABI shape is unchanged)
        let idx = plan.node_indices_i32().unwrap();
        out.push(("node_idx".to_string(), HostTensor::I32(idx, vec![node.h, n])));
    }
    if let Some(dhe) = &plan.dhe {
        out.push((
            "dhe_enc".to_string(),
            HostTensor::F32(dhe.encoding.clone(), vec![n, dhe.encoding_dim]),
        ));
    }
    // graph statics
    match model {
        ModelKind::Gcn => {
            let (idx, w, k) = padded_gcn_adjacency(ds);
            out.push(("adj_idx".to_string(), HostTensor::I32(idx, vec![n, k])));
            out.push(("adj_w".to_string(), HostTensor::F32(w, vec![n, k])));
        }
        ModelKind::Sage => {
            let (src, dst) = ds.graph.mem().to_coo();
            let e = src.len();
            out.push((
                "src".to_string(),
                HostTensor::I32(src.iter().map(|&x| x as i32).collect(), vec![e]),
            ));
            out.push((
                "dst".to_string(),
                HostTensor::I32(dst.iter().map(|&x| x as i32).collect(), vec![e]),
            ));
            let inv_deg: Vec<f32> = (0..n as u32)
                .map(|u| 1.0 / ds.graph.degree(u).max(1) as f32)
                .collect();
            out.push(("inv_deg".to_string(), HostTensor::F32(inv_deg, vec![n, 1])));
        }
        ModelKind::Gat => {
            let (src, dst) = ds.graph.mem().to_coo();
            let e = src.len();
            out.push((
                "src".to_string(),
                HostTensor::I32(src.iter().map(|&x| x as i32).collect(), vec![e]),
            ));
            out.push((
                "dst".to_string(),
                HostTensor::I32(dst.iter().map(|&x| x as i32).collect(), vec![e]),
            ));
        }
    }
    out
}

/// Padded adjacency with GCN renormalization: row u holds its neighbors
/// with `1/sqrt((deg_u+1)(deg_v+1))`, then the self loop `1/(deg_u+1)`,
/// then weight-0 self-pointing padding up to `K = max_deg + 1`.
pub fn padded_gcn_adjacency(ds: &Dataset) -> (Vec<i32>, Vec<f32>, usize) {
    let g = ds.graph.mem();
    let n = g.num_nodes();
    let max_deg = (0..n as u32).map(|u| g.degree(u)).max().unwrap_or(0);
    let k = max_deg + 1;
    let mut idx = vec![0i32; n * k];
    let mut w = vec![0f32; n * k];
    for u in 0..n as u32 {
        let du = (g.degree(u) + 1) as f32;
        let row = u as usize * k;
        let mut slot = 0usize;
        for &v in g.neighbors(u) {
            let dv = (g.degree(v) + 1) as f32;
            idx[row + slot] = v as i32;
            w[row + slot] = 1.0 / (du * dv).sqrt();
            slot += 1;
        }
        // self loop
        idx[row + slot] = u as i32;
        w[row + slot] = 1.0 / du;
        slot += 1;
        // padding: self-pointing, zero weight
        for s in slot..k {
            idx[row + s] = u as i32;
        }
    }
    (idx, w, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{spec, Dataset};
    use crate::embedding::EmbeddingMethod;

    fn small_ds() -> Dataset {
        let mut s = spec("synth-arxiv").unwrap();
        s.n = 500;
        s.communities = 10;
        s.supers = 2;
        Dataset::generate(&s)
    }

    #[test]
    fn gcn_adjacency_rows_sum_reasonably() {
        let ds = small_ds();
        let (idx, w, k) = padded_gcn_adjacency(&ds);
        let n = ds.graph.num_nodes();
        assert_eq!(idx.len(), n * k);
        for u in 0..n {
            let deg = ds.graph.degree(u as u32);
            // occupied slots: deg + 1 (self); rest zero weight
            let nonzero = w[u * k..(u + 1) * k].iter().filter(|&&x| x > 0.0).count();
            assert_eq!(nonzero, deg + 1, "node {u}");
            // all indices valid
            assert!(idx[u * k..(u + 1) * k].iter().all(|&v| (v as usize) < n));
        }
    }

    #[test]
    fn statics_names_match_model() {
        let ds = small_ds();
        let plan = EmbeddingPlan::build(500, 64, &EmbeddingMethod::Full, None, 0);
        let names = |m: ModelKind| -> Vec<String> {
            build_statics(&ds, m, &plan).into_iter().map(|(n, _)| n).collect()
        };
        assert_eq!(names(ModelKind::Gcn), vec!["node_idx", "adj_idx", "adj_w"]);
        assert_eq!(names(ModelKind::Sage), vec!["node_idx", "src", "dst", "inv_deg"]);
        assert_eq!(names(ModelKind::Gat), vec!["node_idx", "src", "dst"]);
    }

    #[test]
    fn coo_shapes_match_graph() {
        let ds = small_ds();
        let plan =
            EmbeddingPlan::build(500, 64, &EmbeddingMethod::HashTrick { buckets: 32 }, None, 0);
        let statics = build_statics(&ds, ModelKind::Sage, &plan);
        let src = statics.iter().find(|(n, _)| n == "src").unwrap();
        assert_eq!(src.1.shape(), &[ds.graph.num_adjacency_entries()]);
    }

    #[test]
    fn inv_deg_is_positive_and_bounded() {
        let ds = small_ds();
        let plan = EmbeddingPlan::build(500, 64, &EmbeddingMethod::Full, None, 0);
        let statics = build_statics(&ds, ModelKind::Sage, &plan);
        if let HostTensor::F32(v, _) = &statics.iter().find(|(n, _)| n == "inv_deg").unwrap().1 {
            assert!(v.iter().all(|&x| x > 0.0 && x <= 1.0));
        } else {
            panic!("inv_deg not f32");
        }
    }
}
