//! Training coordinator: owns the full training lifecycle on the Rust
//! side — parameter/optimizer state, static tensor construction, epoch
//! loops, periodic evaluation, early stopping and result aggregation.
//!
//! Three training paths live here:
//!
//! * [`run_experiment`] — the AOT/PJRT full-batch path: the compiled
//!   train-step HLO is the compute, Python never runs, and the packed
//!   state vector stays device-resident (needs the `pjrt` feature plus
//!   `make artifacts`).
//! * [`MinibatchTrainer`] / [`train_full_batch`] — the host-side path:
//!   GraphSAGE-style neighbor-sampled minibatches composed with
//!   `ComposeEngine::compose_batch` and stepped with host SGD/Adam
//!   ([`Optimizer`]); no artifacts required. The full-batch variant is
//!   the oracle the minibatch path is tested against. By default the
//!   minibatch path runs **pipelined**: a prefetcher samples upcoming
//!   blocks on a dedicated thread while the step's forward, backward
//!   (sharded [`GradBuffer`] accumulation via [`GradShard`]) and
//!   optimizer apply run on the rayon pool — bit-identical to the
//!   serial oracle step at any thread count
//!   (`tests/parallel_train.rs`).
//! * [`ShardedTrainer`] — partition-sharded training: the graph is cut
//!   into `k` shards, each running the minibatch path on its own local
//!   subgraph + partition-aligned table slice, stitched together by a
//!   per-epoch halo exchange (see `sharded`'s module docs). At `k = 1`
//!   it reproduces [`MinibatchTrainer`] bit for bit.
//!
//! The minibatch path is additionally **crash-safe**: [`checkpoint`]
//! snapshots parameters, Adam moments and the `(epoch, batch)` cursor
//! into atomically-published checkpoint directories, and a run resumed
//! from any checkpoint replays the identical loss trajectory bit for
//! bit (`tests/checkpoint.rs`, `tests/crash_resume.rs`).

pub mod checkpoint;
mod minibatch;
mod optim;
mod params;
mod sharded;
mod statics;
mod trainer;

pub use checkpoint::{
    load_latest, save_checkpoint, sweep_stale_temps, CheckpointConfig, CheckpointManifest, Cursor,
    LoadedCheckpoint, RunKey,
};
pub use minibatch::{
    train_full_batch, EdgeDecoder, MinibatchOptions, MinibatchOutcome, MinibatchTrainer, Objective,
};
// shared with the serving path (`crate::serve`), so a served forward
// can never drift from the trainers' evaluation forward
pub(crate) use minibatch::{head_param_names, layer_dims, mean_rows, sage_affine_row};
pub use optim::{GradBuffer, GradShard, Optimizer, OptimizerKind};
pub use params::{gnn_param_shapes, init_full_params};
pub use sharded::{ShardStats, ShardedOutcome, ShardedTrainer};
pub use statics::build_statics;
pub use trainer::{run_experiment, TrainOptions, TrainOutcome};
