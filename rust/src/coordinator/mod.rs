//! Training coordinator: owns the full training lifecycle on the Rust
//! side — parameter/optimizer state, static tensor construction, the
//! epoch loop over the AOT train step, periodic evaluation, early
//! stopping and result aggregation.
//!
//! Python never runs here; the compiled HLO is the only compute.

mod params;
mod statics;
mod trainer;

pub use params::{init_full_params, gnn_param_shapes};
pub use statics::build_statics;
pub use trainer::{run_experiment, TrainOptions, TrainOutcome};
