#!/usr/bin/env python3
"""Throughput-regression gate for the CI bench smokes.

Compares the fresh quick-mode bench records (compose / partition /
minibatch JSON, produced earlier in the smoke job) against the committed
``BENCH_baseline.json`` and fails the job when any matched metric drops
more than the allowed fraction (default 25%). Always writes an
assembled candidate baseline (``bench-baseline-candidate.json``) so the
pin job can commit measured numbers on main pushes.

Bootstrap: the repository is authored in an offline environment, so the
first committed baseline carries ``"bootstrap": true`` and no records.
In that state the gate is skipped (there is nothing trustworthy to
compare against) and the pin job replaces the placeholder with the
candidate measured on CI hardware; from then on the gate is live.

Modes:
    compare      --baseline B --compose C --partition P --minibatch M
                 [--serve S] --out CANDIDATE [--tolerance 0.25]
    is-bootstrap --baseline B      (exit 0 iff the baseline is bootstrap)
"""

import argparse
import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def key_metrics(compose, partition, minibatch, serve):
    """Flatten the record files into {key: throughput} pairs."""
    metrics = {}
    for r in compose:
        metrics[f"compose/{r['method']}/{r['path']}"] = r["elements_per_sec"]
    for r in partition:
        metrics[f"partition/{r['stage']}"] = r["edges_per_sec"]
    r = minibatch
    metrics[f"minibatch/{r['dataset']}/{r['method']}/b{r['batch_size']}"] = r["nodes_per_sec"]
    if serve is not None:
        r = serve
        metrics[f"serve/{r['dataset']}/{r['method']}/cache{r['cache_rows']}"] = (
            r["queries_per_sec"])
    return metrics


def cmd_compare(args):
    baseline = load(args.baseline)
    compose = load(args.compose)
    partition = load(args.partition)
    minibatch = load(args.minibatch)
    serve = load(args.serve) if args.serve else None

    fresh = key_metrics(compose, partition, minibatch, serve)
    candidate = {
        "bootstrap": False,
        "git_sha": os.environ.get("GITHUB_SHA", "unknown"),
        "threads": minibatch.get("threads", 0),
        "metrics": fresh,
        "records": {
            "compose": compose,
            "partition": partition,
            "minibatch": minibatch,
            "serve": serve,
        },
    }
    with open(args.out, "w") as f:
        json.dump(candidate, f, indent=2, sort_keys=True)
    print(f"wrote candidate baseline with {len(fresh)} metrics -> {args.out}")

    if baseline.get("bootstrap"):
        print("committed baseline is a bootstrap placeholder: gate skipped "
              "(the pin job will commit this candidate on the next main push)")
        return 0

    # Absolute throughput is only comparable on the same runner class;
    # a different worker-thread count is the loudest signal the class
    # changed (new runner image / CPU generation). Warn-and-skip there
    # instead of failing unrelated PRs on runner variance.
    base_threads = baseline.get("threads", 0)
    if base_threads and candidate["threads"] and base_threads != candidate["threads"]:
        print(f"runner class changed ({candidate['threads']} threads vs baseline "
              f"{base_threads}): gate skipped — re-pin BENCH_baseline.json from the "
              "bench-baseline-candidate artifact to re-arm it")
        return 0

    old = baseline.get("metrics", {})
    floor = 1.0 - args.tolerance
    failures, compared = [], 0
    for key, prev in sorted(old.items()):
        now = fresh.get(key)
        if now is None or prev <= 0:
            continue  # stage renamed/removed: not a regression signal
        compared += 1
        ratio = now / prev
        marker = "OK " if ratio >= floor else "REG"
        print(f"  {marker} {key}: {now:,.0f} vs baseline {prev:,.0f} ({ratio:.2f}x)")
        if ratio < floor:
            failures.append((key, ratio))
    if not compared:
        print("no overlapping metrics between baseline and fresh records")
        return 0
    if failures:
        print(f"\nFAIL: {len(failures)}/{compared} metrics regressed more than "
              f"{args.tolerance:.0%} vs baseline {baseline.get('git_sha', '?')}:")
        for key, ratio in failures:
            print(f"  {key}: {ratio:.2f}x")
        return 1
    print(f"bench baseline gate passed: {compared} metrics within {args.tolerance:.0%}")
    return 0


def cmd_is_bootstrap(args):
    return 0 if load(args.baseline).get("bootstrap") else 1


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    sub = parser.add_subparsers(dest="mode", required=True)

    cmp_p = sub.add_parser("compare")
    cmp_p.add_argument("--baseline", required=True)
    cmp_p.add_argument("--compose", required=True)
    cmp_p.add_argument("--partition", required=True)
    cmp_p.add_argument("--minibatch", required=True)
    cmp_p.add_argument("--serve", default=None,
                       help="serve-bench record JSON (optional)")
    cmp_p.add_argument("--out", required=True)
    cmp_p.add_argument("--tolerance", type=float, default=0.25)
    cmp_p.set_defaults(func=cmd_compare)

    boot_p = sub.add_parser("is-bootstrap")
    boot_p.add_argument("--baseline", required=True)
    boot_p.set_defaults(func=cmd_is_bootstrap)

    args = parser.parse_args()
    sys.exit(args.func(args))


if __name__ == "__main__":
    main()
