"""AOT pipeline tests: lowering produces loadable HLO text + a manifest
consistent with the ABI."""

import json
import os
import tempfile

import pytest

from compile.aot import default_grid, input_specs, lower_config


def test_default_grid_covers_all_models():
    models = {c["model"] for c in default_grid()}
    assert models == {"gcn", "sage", "gat"}


def test_lower_writes_hlo_text_and_manifest_entries():
    cfg = default_grid(quick=True)[0]
    with tempfile.TemporaryDirectory() as td:
        entries = lower_config(cfg, td)
        assert len(entries) == 2
        for e in entries:
            path = os.path.join(td, e["path"])
            assert os.path.exists(path)
            text = open(path).read()
            # HLO text module with the entry computation
            assert text.startswith("HloModule"), text[:60]
            assert "ENTRY" in text
            # manifest inputs match the ABI spec exactly
            specs = input_specs(cfg, e["mode"])
            assert [(i["name"], tuple(i["shape"])) for i in e["inputs"]] == \
                [(n, tuple(s)) for n, s, _ in specs]


def test_train_artifact_io_counts():
    cfg = default_grid(quick=True)[0]
    with tempfile.TemporaryDirectory() as td:
        train, ev = lower_config(cfg, td)
    assert train["num_outputs"] == 1
    assert ev["num_outputs"] == 1
    packed = train["packed"]
    assert packed["total"] == 3 * packed["param_scalars"] + 2
    assert train["num_params"] == len(packed["params"])
    # state is the first input and matches the packed total
    assert train["inputs"][0]["name"] == "state"
    assert train["inputs"][0]["shape"] == [packed["total"]]


def test_manifest_json_round_trips():
    cfg = default_grid(quick=True)[0]
    with tempfile.TemporaryDirectory() as td:
        entries = lower_config(cfg, td)
        blob = json.dumps({"artifacts": entries})
        back = json.loads(blob)
        assert back["artifacts"][0]["config"]["name"] == cfg["name"]
