"""L2 model tests: shapes, loss behaviour, end-to-end training steps in
python (same jitted function the AOT artifact freezes)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import numpy as onp

from compile.model import forward, gnn_param_specs, loss_fn
from compile.train_step import (build_eval, build_train_step,
                                example_flat_inputs, packed_layout,
                                param_specs, static_specs)
from compile.aot import input_specs


def unpack_params(cfg, state):
    layout, _, _ = packed_layout(cfg)
    return {name: jnp.asarray(state[off:off + int(onp.prod(shape))]
                              ).reshape(shape)
            for name, off, shape in layout}


def tiny_cfg(model="gcn", task="multiclass", use_node=True, use_pos=True):
    emb = {
        "pos_tables": [[3, 8], [9, 4]] if use_pos else [],
        "node_rows": 6 if use_node else 0,
        "h": 2,
        "learned_y": True,
        "dhe": None,
    }
    return {
        "name": f"tiny_{model}",
        "model": model,
        "task": task,
        "n": 40,
        "d": 8,
        "classes": 5,
        "hidden": 8,
        "num_layers": 2,
        "edges": 120,
        "pad_k": 4,
        "lr": 0.05,
        "embedding": emb,
    }


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_eval_logit_shapes(model):
    cfg = tiny_cfg(model)
    flat = example_flat_inputs(cfg, "eval", seed=1)
    logits = build_eval(cfg)(*[jnp.asarray(x) for x in flat])
    assert logits.shape == (40, 5)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_train_step_decreases_loss(model):
    cfg = tiny_cfg(model)
    step = jax.jit(build_train_step(cfg))
    flat = [jnp.asarray(x) for x in example_flat_inputs(cfg, "train", seed=2)]
    losses = []
    for it in range(15):
        flat[0] = step(*flat)
        losses.append(float(flat[0][-1]))
    assert losses[-1] < losses[0] * 0.9, f"{model} losses: {losses[:3]}...{losses[-3:]}"


def test_multilabel_loss_path():
    cfg = tiny_cfg("gcn", task="multilabel")
    step = jax.jit(build_train_step(cfg))
    flat = [jnp.asarray(x) for x in example_flat_inputs(cfg, "train", seed=3)]
    out = step(*flat)
    assert np.isfinite(float(out[-1]))


def test_step_counter_increments_and_params_change():
    cfg = tiny_cfg("gcn")
    _, psize, _ = packed_layout(cfg)
    step = jax.jit(build_train_step(cfg))
    flat = [jnp.asarray(x) for x in example_flat_inputs(cfg, "train", seed=9)]
    s0 = flat[0]
    s1 = step(*flat)
    assert float(s1[3 * psize]) == float(s0[3 * psize]) + 1.0
    assert not bool(jnp.allclose(s0[:psize], s1[:psize]))


def test_pallas_and_ref_forward_agree():
    cfg = tiny_cfg("gcn")
    flat = example_flat_inputs(cfg, "eval", seed=4)
    params = unpack_params(cfg, flat[0])
    statics = {name: jnp.asarray(flat[1 + i])
               for i, (name, _, _) in enumerate(static_specs(cfg))}
    a = forward(cfg, params, statics, use_pallas=True)
    b = forward(cfg, params, statics, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("model", ["gcn", "sage", "gat"])
def test_grads_flow_to_every_param(model):
    cfg = tiny_cfg(model)
    flat = example_flat_inputs(cfg, "train", seed=5)
    sspecs = static_specs(cfg)
    params = unpack_params(cfg, flat[0])
    statics = {name: jnp.asarray(flat[1 + i])
               for i, (name, _, _) in enumerate(sspecs)}
    labels = jnp.asarray(flat[1 + len(sspecs)])
    mask = jnp.asarray(flat[2 + len(sspecs)])
    grads = jax.grad(
        lambda ps: loss_fn(cfg, ps, statics, labels, mask))(params)
    for name, g in grads.items():
        norm = float(jnp.linalg.norm(g))
        assert np.isfinite(norm), name
        # every table should receive some signal on a connected-ish graph
        if name != "node_y":
            assert norm > 0, f"zero grad for {name}"


def test_mask_limits_loss_support():
    cfg = tiny_cfg("gcn")
    flat = example_flat_inputs(cfg, "train", seed=6)
    sspecs = static_specs(cfg)
    params = unpack_params(cfg, flat[0])
    statics = {name: jnp.asarray(flat[1 + i])
               for i, (name, _, _) in enumerate(sspecs)}
    labels = jnp.asarray(flat[1 + len(sspecs)])
    # flipping labels OUTSIDE the mask must not change the loss
    mask = jnp.zeros(cfg["n"]).at[:10].set(1.0)
    l1 = loss_fn(cfg, params, statics, labels, mask)
    labels2 = labels.at[20:].set((labels[20:] + 1) % cfg["classes"])
    l2 = loss_fn(cfg, params, statics, labels2, mask)
    assert float(jnp.abs(l1 - l2)) < 1e-6


def test_input_specs_abi_is_stable():
    """Golden ABI: [state, statics..., labels, mask]; packed layout order
    = pos tables, node_x, node_y, gnn params.

    The Rust runtime builds its packed state from this exact order; this
    test pins it so a refactor cannot silently shift the convention.
    """
    cfg = tiny_cfg("gcn")
    names = [n for n, _, _ in input_specs(cfg, "train")]
    assert names == ["state", "z", "node_idx", "adj_idx", "adj_w",
                     "labels", "mask"]
    eval_names = [n for n, _, _ in input_specs(cfg, "eval")]
    assert eval_names == ["state", "z", "node_idx", "adj_idx", "adj_w"]
    layout, psize, total = packed_layout(cfg)
    assert [n for n, _, _ in layout] == [
        "pos_0", "pos_1", "node_x", "node_y",
        "gcn_w0", "gcn_b0", "gcn_w1", "gcn_b1"]
    # state shape in the spec matches the layout total
    state_shape = input_specs(cfg, "train")[0][1]
    assert state_shape == [total] == [3 * psize + 2]
