"""L1 kernel correctness: Pallas (interpret) vs pure-jnp reference.

Hypothesis sweeps shapes, level counts, hash counts and dtypes; gradient
checks verify the custom_vjp adjoints against jax.grad of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gather_combine import (compose_embedding,
                                            compose_embedding_pallas)
from compile.kernels.ref import (compose_embedding_ref, dhe_ref,
                                 spmm_padded_ref)
from compile.kernels.spmm_padded import spmm_padded, spmm_padded_pallas


def make_inputs(rng, n, d, num_pos, num_hash, learned_y):
    pos, z = [], None
    if num_pos:
        rows = 4
        zs = []
        for j in range(num_pos):
            dj = max(d >> j, 1)
            pos.append(jnp.asarray(rng.standard_normal((rows, dj)), jnp.float32))
            zs.append(rng.integers(0, rows, n))
            rows *= 3
        z = jnp.asarray(np.stack(zs), jnp.int32)
    X = idx = y = None
    if num_hash:
        b = 7
        X = jnp.asarray(rng.standard_normal((b, d)), jnp.float32)
        idx = jnp.asarray(rng.integers(0, b, (num_hash, n)), jnp.int32)
        if learned_y:
            y = jnp.asarray(rng.standard_normal((n, num_hash)), jnp.float32)
    return pos, z, X, idx, y


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 400),
    d=st.sampled_from([4, 8, 16, 32]),
    num_pos=st.integers(0, 3),
    num_hash=st.integers(0, 3),
    learned_y=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_gather_combine_matches_ref(n, d, num_pos, num_hash, learned_y, seed):
    if num_pos == 0 and num_hash == 0:
        return
    rng = np.random.default_rng(seed)
    pos, z, X, idx, y = make_inputs(rng, n, d, num_pos, num_hash, learned_y)
    out = compose_embedding_pallas(pos, z, X, idx, y, d)
    ref = compose_embedding_ref(pos, z, X, idx, y, d)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_gather_combine_block_boundary_sizes():
    # n exactly at / around the 256 tile boundary
    rng = np.random.default_rng(0)
    for n in (255, 256, 257, 512):
        pos, z, X, idx, y = make_inputs(rng, n, 8, 2, 2, True)
        out = compose_embedding_pallas(pos, z, X, idx, y, 8)
        ref = compose_embedding_ref(pos, z, X, idx, y, 8)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_gather_combine_grads_match_ref(seed):
    rng = np.random.default_rng(seed)
    n, d = 50, 8
    pos, z, X, idx, y = make_inputs(rng, n, d, 2, 2, True)
    g_out = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)

    def pallas_loss(pos_t, xt, yt):
        return jnp.sum(compose_embedding(tuple(pos_t), z, xt, idx, yt) * g_out)

    def ref_loss(pos_t, xt, yt):
        return jnp.sum(compose_embedding_ref(list(pos_t), z, xt, idx, yt, d) * g_out)

    gp = jax.grad(pallas_loss, argnums=(0, 1, 2))(tuple(pos), X, y)
    gr = jax.grad(ref_loss, argnums=(0, 1, 2))(tuple(pos), X, y)
    for a, b in zip(jax.tree_util.tree_leaves(gp), jax.tree_util.tree_leaves(gr)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    n_src=st.integers(1, 300),
    k=st.integers(1, 12),
    d=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31),
)
def test_spmm_matches_ref(n, n_src, k, d, seed):
    rng = np.random.default_rng(seed)
    h = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, (n, k)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    out = spmm_padded_pallas(h, idx, w)
    ref = spmm_padded_ref(h, idx, w)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_spmm_padding_weight_zero_is_noop():
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.standard_normal((10, 4)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 10, (6, 3)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((6, 3)), jnp.float32)
    # zero the last slot: result must equal a 2-slot spmm
    w0 = w.at[:, 2].set(0.0)
    a = spmm_padded_pallas(h, idx, w0)
    b = spmm_padded_ref(h, idx[:, :2], w[:, :2].at[:, :].set(w0[:, :2]))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_spmm_grads_match_ref(seed):
    rng = np.random.default_rng(seed)
    n, n_src, k, d = 20, 15, 4, 8
    h = jnp.asarray(rng.standard_normal((n_src, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_src, (n, k)), jnp.int32)
    w = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    g_out = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)

    gp = jax.grad(lambda hh, ww: jnp.sum(spmm_padded(hh, idx, ww) * g_out),
                  argnums=(0, 1))(h, w)
    gr = jax.grad(lambda hh, ww: jnp.sum(spmm_padded_ref(hh, idx, ww) * g_out),
                  argnums=(0, 1))(h, w)
    np.testing.assert_allclose(gp[0], gr[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gp[1], gr[1], rtol=1e-4, atol=1e-4)


def test_dhe_ref_shapes_and_relu():
    rng = np.random.default_rng(1)
    enc = jnp.asarray(rng.uniform(-1, 1, (9, 6)), jnp.float32)
    w0 = jnp.asarray(rng.standard_normal((6, 5)), jnp.float32)
    b0 = jnp.zeros((1, 5), jnp.float32)
    wo = jnp.asarray(rng.standard_normal((5, 3)), jnp.float32)
    bo = jnp.zeros((1, 3), jnp.float32)
    out = dhe_ref(enc, [w0], [b0], wo, bo)
    assert out.shape == (9, 3)
    # relu really clips: zero weights + negative bias -> hidden = 0 -> bias out
    out2 = dhe_ref(enc, [jnp.zeros_like(w0)], [b0 - 1.0], wo, bo)
    np.testing.assert_allclose(out2, jnp.broadcast_to(bo, (9, 3)), atol=1e-5)
