"""L1/L2 performance analysis for EXPERIMENTS.md §Perf.

Run AFTER `make artifacts`:
    cd python && python -m compile.perf_report

Reports, for a representative config (synth-arxiv GCN + PosHashEmb
Intra h=2):
  * XLA cost analysis of the lowered train step (flops, bytes accessed),
  * HLO op histogram (fusion sanity: no stray transcendental storms),
  * VMEM footprint of the Pallas gather_combine tile at several block
    sizes — the TPU-facing metric interpret mode cannot measure, and
  * arithmetic-intensity / roofline notes for the embedding layer.
"""

from __future__ import annotations

import collections
import json
import re

import jax
import numpy as np

from .train_step import build_train_step, packed_layout, static_specs
from .aot import input_specs

_DT = {"f32": np.float32, "i32": np.int32}


def rep_config():
    """synth-arxiv / GCN / PosHashEmb Intra h=2 (paper default)."""
    k, c = 21, 17  # default_k(6000)=21 (paper's arxiv k), c=ceil(sqrt(n/k))
    return {
        "name": "perf_probe", "model": "gcn", "task": "multiclass",
        "n": 6000, "d": 64, "classes": 40, "hidden": 64, "num_layers": 2,
        "edges": 0, "pad_k": 30, "lr": 0.01,
        "embedding": {
            "pos_tables": [[k, 64], [k * k, 32], [k ** 3, 16]],
            "node_rows": k * c, "h": 2, "learned_y": True, "dhe": None,
        },
    }


def main():
    cfg = rep_config()
    specs = input_specs(cfg, "train")
    args = [jax.ShapeDtypeStruct(tuple(s), _DT[d]) for _, s, d in specs]
    lowered = jax.jit(build_train_step(cfg)).lower(*args)
    compiled = lowered.compile()
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
    except Exception:
        ca = {}
    flops = ca.get("flops", float("nan"))
    bytes_ = ca.get("bytes accessed", float("nan"))
    print("== L2 cost analysis (train step, arxiv/gcn/intra_h2) ==")
    print(f"flops/step:          {flops:,.0f}")
    print(f"bytes accessed/step: {bytes_:,.0f}")
    if flops == flops and bytes_ == bytes_:
        print(f"arithmetic intensity: {flops / max(bytes_, 1):.2f} flop/byte")

    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    ops = collections.Counter(
        m.group(1) for m in re.finditer(r"= *[a-z0-9\[\]_]+ ([a-z-]+)\(", hlo))
    print("\n== HLO op histogram (top 14) ==")
    for op, cnt in ops.most_common(14):
        print(f"  {op:<24} {cnt}")

    # --- L1: VMEM footprint of the gather_combine tile ---
    layout, psize, total = packed_layout(cfg)
    emb = cfg["embedding"]
    tables = sum(r * c for r, c in emb["pos_tables"]) + emb["node_rows"] * cfg["d"]
    print("\n== L1 Pallas gather_combine VMEM footprint ==")
    print(f"embedding tables resident/tile: {tables * 4 / 1024:.1f} KiB "
          f"(paper's point: compressed tables FIT in VMEM ~16 MiB)")
    for bn in (128, 256, 512, 1024):
        z = 3 * bn * 4
        idx = 2 * bn * 4
        y = bn * 2 * 4
        out = bn * cfg["d"] * 4
        tile = tables * 4 + z + idx + y + out
        print(f"  block_n={bn:<5} tile total {tile / 1024:8.1f} KiB "
              f"({'fits' if tile < 16 * 2**20 else 'EXCEEDS'} VMEM)")
    # gather+combine arithmetic intensity
    gathers = 5  # 3 pos levels + 2 hash rows
    flops_node = gathers * cfg["d"]  # adds + weighted adds
    bytes_node = gathers * cfg["d"] * 4 + cfg["d"] * 4
    print(f"\nembedding compose: ~{flops_node} flop/node over {bytes_node} B/node "
          f"-> {flops_node / bytes_node:.2f} flop/byte (bandwidth-bound, as expected "
          f"for gathers; MXU engaged by the downstream dense layers instead)")
    full_bytes = cfg["n"] * cfg["d"] * 4
    comp_bytes = tables * 4
    print(f"HBM traffic for the table read, FullEmb vs PosHashEmb: "
          f"{full_bytes/2**20:.1f} MiB -> {comp_bytes/2**20:.2f} MiB per full-graph epoch "
          f"({full_bytes/comp_bytes:.0f}x less)")


if __name__ == "__main__":
    main()
