"""Layer-2 training step: fwd + backward (jax.grad) + Adam, as one jitted
function whose flat input/output signature is the artifact ABI.

ABI (mirrored by rust/src/runtime + coordinator):

  train inputs : [state, *statics, labels, mask]
  train output : state'                      (single f32 array!)
  eval  inputs : [state, *statics]
  eval  output : logits

``state`` is ONE flat f32 vector packing, in order:

  [ params (param_specs order, row-major) | adam_m | adam_v | step | loss ]

so its length is ``3 * S + 2`` where S = total parameter scalars. The
packed design is deliberate: xla_extension 0.5.1's PJRT wrapper cannot
download tuple buffers (``to_literal_sync`` aborts on tuple shapes), so
multi-output train steps are unusable from Rust. A single-array state
also keeps the training loop zero-copy: the Rust coordinator feeds the
output buffer of epoch t straight back in at epoch t+1.

Parameter order = embedding_param_specs ++ gnn_param_specs. Static order
= embedding_static_specs ++ graph_static_specs. All recorded in the
manifest so the Rust side never guesses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .embeddings import (embedding_param_specs, embedding_static_specs,
                         init_embedding_params)
from .model import gnn_param_specs, graph_static_specs, loss_fn, forward

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def param_specs(cfg):
    return (embedding_param_specs(cfg["embedding"], cfg["n"], cfg["d"])
            + gnn_param_specs(cfg))


def static_specs(cfg):
    return (embedding_static_specs(cfg["embedding"], cfg["n"], cfg["d"])
            + graph_static_specs(cfg))


def label_spec(cfg):
    if cfg["task"] == "multiclass":
        return ("labels", (cfg["n"],), "i32")
    return ("labels", (cfg["n"], cfg["classes"]), "f32")


def packed_layout(cfg):
    """[(name, offset, shape)] for params within the packed state, plus
    total state length."""
    specs = param_specs(cfg)
    layout = []
    off = 0
    for name, shape in specs:
        size = int(np.prod(shape))
        layout.append((name, off, shape))
        off += size
    total = 3 * off + 2  # params + m + v + step + loss
    return layout, off, total


def _unpack(state, layout, base):
    """Dict of param tensors from the packed state at section ``base``."""
    out = {}
    for name, off, shape in layout:
        size = int(np.prod(shape))
        out[name] = jax.lax.dynamic_slice(state, (base + off,),
                                          (size,)).reshape(shape)
    return out


def _pack(trees, layout, extra):
    """Concatenate param dicts (in layout order) + extra scalars."""
    parts = []
    for tree in trees:
        for name, _, _ in layout:
            parts.append(tree[name].reshape(-1))
    parts.append(extra)
    return jnp.concatenate(parts)


def adam_update(p, g, m, v, c1, c2, lr):
    """One Adam update. `c1 = 1/(1-b1^t)`, `c2 = 1/(1-b2^t)` are the
    bias corrections, hoisted by the caller so the `pow` ops appear once
    per step instead of once per parameter tensor (§Perf: 18 -> 2 power
    ops in the lowered HLO)."""
    m = ADAM_B1 * m + (1 - ADAM_B1) * g
    v = ADAM_B2 * v + (1 - ADAM_B2) * g * g
    mhat = m * c1
    vhat = v * c2
    return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


def build_train_step(cfg, use_pallas=True):
    """Returns f(state, *statics, labels, mask) -> state'."""
    layout, psize, total = packed_layout(cfg)
    sspecs = static_specs(cfg)
    num_s = len(sspecs)
    lr = cfg.get("lr", 0.01)

    def step_fn(state, *rest):
        statics = {name: rest[i] for i, (name, _, _) in enumerate(sspecs)}
        labels = rest[num_s]
        mask = rest[num_s + 1]
        params = _unpack(state, layout, 0)
        m = _unpack(state, layout, psize)
        v = _unpack(state, layout, 2 * psize)
        t = state[3 * psize]  # 1-based step counter

        def objective(ps):
            return loss_fn(cfg, ps, statics, labels, mask, use_pallas)

        loss, grads = jax.value_and_grad(objective)(params)
        c1 = 1.0 / (1.0 - ADAM_B1 ** t)
        c2 = 1.0 / (1.0 - ADAM_B2 ** t)
        new_p, new_m, new_v = {}, {}, {}
        for name, _, _ in layout:
            p2, m2, v2 = adam_update(params[name], grads[name], m[name],
                                     v[name], c1, c2, lr)
            new_p[name], new_m[name], new_v[name] = p2, m2, v2
        extra = jnp.stack([t + 1.0, loss])
        return _pack([new_p, new_m, new_v], layout, extra)

    return step_fn


def build_eval(cfg, use_pallas=True):
    """Returns f(state, *statics) -> logits."""
    layout, _, _ = packed_layout(cfg)
    sspecs = static_specs(cfg)

    def eval_fn(state, *rest):
        params = _unpack(state, layout, 0)
        statics = {name: rest[i] for i, (name, _, _) in enumerate(sspecs)}
        return forward(cfg, params, statics, use_pallas)

    return eval_fn


# ---------------------------------------------------------------------------
# example args (shape-only lowering + tests)

_DTYPES = {"f32": np.float32, "i32": np.int32}


def init_packed_state(cfg, seed=0):
    """Initial packed state: init params, zero moments, step=1, loss=0."""
    rng = np.random.RandomState(seed)
    layout, psize, total = packed_layout(cfg)
    params = init_embedding_params(cfg["embedding"], cfg["n"], cfg["d"], seed)
    for name, (rows, cols) in gnn_param_specs(cfg):
        if "_b" in name and "_w" not in name:
            params[name] = np.zeros((rows, cols), np.float32)
        else:
            a = 1.0 / np.sqrt(rows)
            params[name] = rng.uniform(-a, a, (rows, cols)).astype(np.float32)
    state = np.zeros(total, np.float32)
    for name, off, shape in layout:
        state[off:off + int(np.prod(shape))] = params[name].reshape(-1)
    state[3 * psize] = 1.0  # step counter (1-based)
    return state


def example_statics(cfg, seed=0):
    """Random-but-valid static arrays for shape-only lowering."""
    rng = np.random.RandomState(seed + 1)
    out = []
    for name, shape, dt in static_specs(cfg):
        if name == "z":
            levels = cfg["embedding"]["pos_tables"]
            arr = np.stack([rng.randint(0, rows, cfg["n"])
                            for rows, _ in levels]).astype(np.int32)
        elif name == "node_idx":
            arr = rng.randint(0, cfg["embedding"]["node_rows"],
                              shape).astype(np.int32)
        elif name in ("adj_idx", "src", "dst"):
            arr = rng.randint(0, cfg["n"], shape).astype(np.int32)
        elif name == "adj_w":
            arr = (rng.rand(*shape) * 0.1).astype(np.float32)
        elif name == "inv_deg":
            arr = (1.0 / (1.0 + rng.randint(1, 10, shape))).astype(np.float32)
        elif name == "dhe_enc":
            arr = rng.uniform(-1, 1, shape).astype(np.float32)
        else:
            arr = np.zeros(shape, _DTYPES[dt])
        out.append(arr)
    return out


def example_flat_inputs(cfg, mode, seed=0):
    """Numpy example arrays matching the flat train/eval signature."""
    rng = np.random.RandomState(seed)
    flat = [init_packed_state(cfg, seed)]
    flat += example_statics(cfg, seed)
    if mode == "train":
        if cfg["task"] == "multiclass":
            flat.append(rng.randint(0, cfg["classes"],
                                    (cfg["n"],)).astype(np.int32))
        else:
            flat.append(rng.randint(0, 2, (cfg["n"], cfg["classes"]))
                        .astype(np.float32))
        flat.append((rng.rand(cfg["n"]) < 0.6).astype(np.float32))  # mask
    return flat
