"""Layer-1 Pallas kernel: fused position + hashed-node embedding composition.

This is the paper's compute hot-spot: for every node, gather its L
hierarchy-level rows and its h hashed pool rows, apply importance
weights, and sum (Eq. 7 = Eq. 11 + Eq. 12/13). The kernel tiles the node
axis; the embedding tables — which the paper's whole point is to make
small — stay fully resident per tile (VMEM-resident on TPU; see
DESIGN.md §Hardware-Adaptation).

MUST run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls (real-TPU lowering). Interpret mode lowers to plain
HLO ops, so the kernel embeds in the AOT artifact and runs from Rust.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Node-axis tile. 8×128-friendly; the default suits both the small test
# graphs and the synth datasets (n up to ~49k → ≤ 192 grid steps).
DEFAULT_BLOCK_N = 256


def _kernel(z_ref, idx_ref, y_ref, *refs, num_pos, num_hash, d, dims):
    """One node-tile of the composition.

    refs = (*pos_tables, node_table?, o_ref): pallas passes inputs then
    the output ref last. ``dims[j]`` is the width of position level j.
    """
    o_ref = refs[-1]
    pos_refs = refs[:num_pos]
    node_ref = refs[num_pos] if num_hash > 0 else None

    bn = o_ref.shape[0]
    v = jnp.zeros((bn, d), dtype=jnp.float32)
    for j in range(num_pos):
        tbl = pos_refs[j][...]  # [m_j, d_j] — table resident per tile
        rows = tbl[z_ref[j, :]]  # [bn, d_j]
        if dims[j] == d:
            v = v + rows
        else:
            # zero-extend level j to width d (Eq. 11 alignment)
            v = v.at[:, : dims[j]].add(rows)
    if node_ref is not None:
        pool = node_ref[...]  # [rows, d]
        for t in range(num_hash):
            rows = pool[idx_ref[t, :]]  # [bn, d]
            w = y_ref[:, t : t + 1]  # [bn, 1]
            v = v + rows * w
    o_ref[...] = v


def compose_embedding_pallas(pos_tables, z, node_table, node_idx, node_y, d,
                             block_n: int = DEFAULT_BLOCK_N):
    """Pallas-fused equivalent of ``ref.compose_embedding_ref``.

    Shapes as in the reference; ``node_y=None`` means unweighted (ones).
    The node axis is padded to a multiple of ``block_n`` and the result
    sliced back, so any n works.
    """
    num_pos = len(pos_tables)
    if num_pos:
        n = z.shape[1]
    else:
        n = node_idx.shape[1]
    num_hash = 0 if node_table is None else node_idx.shape[0]

    n_pad = -(-n // block_n) * block_n
    if num_pos:
        z_in = jnp.pad(z, ((0, 0), (0, n_pad - n)))
    else:
        z_in = jnp.zeros((1, n_pad), dtype=jnp.int32)
    if num_hash:
        idx_in = jnp.pad(node_idx, ((0, 0), (0, n_pad - n)))
        if node_y is None:
            y_in = jnp.ones((n_pad, num_hash), dtype=jnp.float32)
        else:
            y_in = jnp.pad(node_y, ((0, n_pad - n), (0, 0)))
    else:
        idx_in = jnp.zeros((1, n_pad), dtype=jnp.int32)
        y_in = jnp.ones((n_pad, 1), dtype=jnp.float32)

    dims = tuple(t.shape[1] for t in pos_tables)
    kernel = functools.partial(
        _kernel, num_pos=num_pos, num_hash=num_hash, d=d, dims=dims)

    in_specs = [
        pl.BlockSpec(z_in.shape[:1] + (block_n,), lambda i: (0, i)),   # z
        pl.BlockSpec(idx_in.shape[:1] + (block_n,), lambda i: (0, i)),  # idx
        pl.BlockSpec((block_n, y_in.shape[1]), lambda i: (i, 0)),       # y
    ]
    operands = [z_in, idx_in, y_in]
    for t in pos_tables:
        in_specs.append(pl.BlockSpec(t.shape, lambda i: (0, 0)))
        operands.append(t)
    if num_hash:
        in_specs.append(pl.BlockSpec(node_table.shape, lambda i: (0, 0)))
        operands.append(node_table)

    out = pl.pallas_call(
        kernel,
        grid=(n_pad // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*operands)
    return out[:n]


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward + analytic backward.
#
# ``pl.pallas_call`` defines no VJP, so the train step (jax.grad) uses this
# custom_vjp: primal = the kernel above; backward = the exact adjoint of
# gather+weighted-sum (scatter-adds into the tables, row-dots for the
# importance weights). Gradients are verified against the pure-jnp
# reference in python/tests/test_kernel.py.

import numpy as _np
from jax import dtypes as _dtypes


def _int_zero(x):
    """float0 cotangent for integer primal inputs."""
    if x is None:
        return None
    return _np.zeros(x.shape, dtype=_dtypes.float0)


@jax.custom_vjp
def compose_embedding(pos_tables, z, node_table, node_idx, node_y):
    """Differentiable fused composition. d inferred from table shapes."""
    d = pos_tables[0].shape[1] if pos_tables else node_table.shape[1]
    return compose_embedding_pallas(list(pos_tables), z, node_table,
                                    node_idx, node_y, d)


def _compose_fwd(pos_tables, z, node_table, node_idx, node_y):
    out = compose_embedding(pos_tables, z, node_table, node_idx, node_y)
    return out, (pos_tables, z, node_table, node_idx, node_y)


def _compose_bwd(res, g):
    pos_tables, z, node_table, node_idx, node_y = res
    # position tables: scatter-add the leading d_j slice of g per level
    g_pos = []
    for j, tbl in enumerate(pos_tables):
        dj = tbl.shape[1]
        g_pos.append(jnp.zeros_like(tbl).at[z[j]].add(g[:, :dj]))
    g_pos = tuple(g_pos)
    g_table = None
    g_y = None
    if node_table is not None:
        h = node_idx.shape[0]
        g_table = jnp.zeros_like(node_table)
        for t in range(h):
            contrib = g if node_y is None else g * node_y[:, t:t + 1]
            g_table = g_table.at[node_idx[t]].add(contrib)
        if node_y is not None:
            cols = [jnp.sum(g * node_table[node_idx[t]], axis=1)
                    for t in range(h)]
            g_y = jnp.stack(cols, axis=1)
    return (g_pos, _int_zero(z), g_table, _int_zero(node_idx), g_y)


compose_embedding.defvjp(_compose_fwd, _compose_bwd)
