"""Layer-1 Pallas kernel: padded-CSR SpMM (neighbor aggregation).

GNN message passing as a dense-regular kernel: the coordinator (Rust)
pads every adjacency row to K slots (`adj_idx`, weight 0 on padding), so
aggregation is `out[i] = Σ_k adj_w[i,k] · H[adj_idx[i,k]]` — a gather
followed by a weighted reduction that tiles cleanly on the node axis.
On TPU the feature matrix streams HBM→VMEM per tile and the weighted
reduction maps onto 8×128 vector lanes; on CPU we run interpret mode.

Used by the GCN forward path; SAGE/GAT use XLA segment ops instead
(ragged softmax does not pad well) — see model.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _kernel(idx_ref, w_ref, h_ref, o_ref):
    idx = idx_ref[...]          # [bn, K]
    w = w_ref[...]              # [bn, K]
    h = h_ref[...]              # [n_src, d] resident
    gathered = h[idx]           # [bn, K, d]
    o_ref[...] = jnp.einsum("nk,nkd->nd", w, gathered)


def spmm_padded_pallas(h, adj_idx, adj_w, block_n: int = DEFAULT_BLOCK_N):
    """Pallas equivalent of ``ref.spmm_padded_ref``."""
    n, k = adj_idx.shape
    d = h.shape[1]
    n_pad = -(-n // block_n) * block_n
    idx_in = jnp.pad(adj_idx, ((0, n_pad - n), (0, 0)))
    w_in = jnp.pad(adj_w, ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec(h.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), jnp.float32),
        interpret=True,
    )(idx_in, w_in, h)
    return out[:n]


# ---------------------------------------------------------------------------
# Differentiable wrapper (see gather_combine.py for the rationale).

import numpy as _np
from jax import dtypes as _dtypes


@jax.custom_vjp
def spmm_padded(h, adj_idx, adj_w):
    """Differentiable padded-CSR SpMM (Pallas forward)."""
    return spmm_padded_pallas(h, adj_idx, adj_w)


def _spmm_fwd(h, adj_idx, adj_w):
    return spmm_padded(h, adj_idx, adj_w), (h, adj_idx, adj_w)


def _spmm_bwd(res, g):
    h, adj_idx, adj_w = res
    d = h.shape[1]
    # dL/dh: scatter-add w[i,k] * g[i] into row adj_idx[i,k]
    contrib = (adj_w[..., None] * g[:, None, :]).reshape(-1, d)
    g_h = jnp.zeros_like(h).at[adj_idx.reshape(-1)].add(contrib)
    # dL/dw[i,k] = <g[i], h[adj_idx[i,k]]>
    g_w = jnp.einsum("nd,nkd->nk", g, h[adj_idx])
    g_idx = _np.zeros(adj_idx.shape, dtype=_dtypes.float0)
    return (g_h, g_idx, g_w)


spmm_padded.defvjp(_spmm_fwd, _spmm_bwd)
