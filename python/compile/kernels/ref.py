"""Pure-jnp reference oracles for the Pallas kernels.

These are the ground truth for kernel correctness (pytest compares the
Pallas interpret-mode outputs against these) and are also what the L2
model falls back to when ``use_pallas=False``.
"""

from __future__ import annotations

import jax.numpy as jnp


def compose_embedding_ref(pos_tables, z, node_table, node_idx, node_y, d):
    """Reference composition of the PosHashEmb embedding matrix (Eq. 7).

    Args:
      pos_tables: list of ``[m_j, d_j]`` arrays (may be empty). Level j
        contributes to the first ``d_j`` output coordinates (zero-extend).
      z: ``[L, n]`` int32 membership matrix (ignored when no pos tables).
      node_table: ``[rows, d]`` shared pool or None.
      node_idx: ``[h, n]`` int32 hash indices (ignored when no node table).
      node_y: ``[n, h]`` importance weights or None (treated as ones).
      d: output embedding dim.

    Returns:
      ``[n, d]`` float32 embedding matrix.
    """
    if pos_tables:
        n = z.shape[1]
    else:
        n = node_idx.shape[1]
    v = jnp.zeros((n, d), dtype=jnp.float32)
    for j, table in enumerate(pos_tables):
        dj = table.shape[1]
        rows = table[z[j]]  # [n, dj]
        v = v.at[:, :dj].add(rows)
    if node_table is not None:
        h = node_idx.shape[0]
        for t in range(h):
            rows = node_table[node_idx[t]]  # [n, d]
            if node_y is not None:
                rows = rows * node_y[:, t : t + 1]
            v = v + rows
    return v


def spmm_padded_ref(h, adj_idx, adj_w):
    """Reference padded-CSR SpMM: ``out[i] = sum_k adj_w[i,k] * h[adj_idx[i,k]]``.

    Args:
      h: ``[n_src, d]`` node features.
      adj_idx: ``[n, K]`` int32 neighbor ids, padded arbitrarily.
      adj_w: ``[n, K]`` float32 edge coefficients, 0 at padding.

    Returns:
      ``[n, d]`` aggregated features.
    """
    gathered = h[adj_idx]  # [n, K, d]
    return jnp.einsum("nk,nkd->nd", adj_w, gathered)


def dhe_ref(encoding, weights, biases, out_w, out_b):
    """Reference DHE MLP forward: relu hidden layers + linear output."""
    act = encoding
    for w, b in zip(weights, biases):
        act = jnp.maximum(act @ w + b, 0.0)
    return act @ out_w + out_b
