"""Layer-2 embedding composition: every method of the paper as a JAX
function over a parameter dict + static index arrays.

The canonical parameter order and the static-input order MUST match the
Rust side (`rust/src/embedding/plan.rs::param_shapes`,
`rust/src/runtime/artifact.rs`); `python/tests/test_param_layout.py`
pins the convention.
"""

from __future__ import annotations

import numpy as np

from .kernels.gather_combine import compose_embedding
from .kernels.ref import compose_embedding_ref, dhe_ref


def embedding_param_specs(emb_cfg, n, d):
    """[(name, (rows, cols))] in canonical order for one embedding config.

    emb_cfg keys: pos_tables ([[rows, cols], ...]), node_rows (0 = none),
    h, learned_y (bool), dhe (None or dict).
    """
    specs = []
    for j, (rows, cols) in enumerate(emb_cfg.get("pos_tables", [])):
        specs.append((f"pos_{j}", (rows, cols)))
    if emb_cfg.get("node_rows", 0):
        specs.append(("node_x", (emb_cfg["node_rows"], d)))
        if emb_cfg.get("learned_y", False):
            specs.append(("node_y", (n, emb_cfg["h"])))
    dhe = emb_cfg.get("dhe")
    if dhe:
        in_dim = dhe["encoding_dim"]
        for l in range(dhe["layers"]):
            specs.append((f"dhe_w{l}", (in_dim, dhe["hidden"])))
            specs.append((f"dhe_b{l}", (1, dhe["hidden"])))
            in_dim = dhe["hidden"]
        specs.append(("dhe_wout", (in_dim, d)))
        specs.append(("dhe_bout", (1, d)))
    return specs


def embedding_static_specs(emb_cfg, n, d):
    """[(name, shape, dtype)] of static inputs the composition needs."""
    statics = []
    pos = emb_cfg.get("pos_tables", [])
    if pos:
        statics.append(("z", (len(pos), n), "i32"))
    if emb_cfg.get("node_rows", 0):
        statics.append(("node_idx", (emb_cfg["h"], n), "i32"))
    dhe = emb_cfg.get("dhe")
    if dhe:
        statics.append(("dhe_enc", (n, dhe["encoding_dim"]), "f32"))
    return statics


def compose(emb_cfg, params, statics, n, d, use_pallas=True):
    """Compute the [n, d] embedding matrix V (Eq. 7)."""
    pos_tables = [params[f"pos_{j}"]
                  for j in range(len(emb_cfg.get("pos_tables", [])))]
    z = statics.get("z")
    node_table = params.get("node_x")
    node_idx = statics.get("node_idx")
    node_y = params.get("node_y")
    dhe = emb_cfg.get("dhe")

    if pos_tables or node_table is not None:
        if use_pallas:
            v = compose_embedding(tuple(pos_tables), z, node_table,
                                  node_idx, node_y)
        else:
            v = compose_embedding_ref(pos_tables, z, node_table, node_idx,
                                      node_y, d)
    else:
        import jax.numpy as jnp
        v = jnp.zeros((n, d), dtype=jnp.float32)
    if dhe:
        ws = [params[f"dhe_w{l}"] for l in range(dhe["layers"])]
        bs = [params[f"dhe_b{l}"] for l in range(dhe["layers"])]
        v = v + dhe_ref(statics["dhe_enc"], ws, bs,
                        params["dhe_wout"], params["dhe_bout"])
    return v


def init_embedding_params(emb_cfg, n, d, seed=0):
    """Numpy init (tests + aot example args). Mirrors the Rust policy:
    uniform(±1/sqrt(cols)) tables, ones for node_y, zero dhe biases."""
    rng = np.random.RandomState(seed)
    params = {}
    for name, (rows, cols) in embedding_param_specs(emb_cfg, n, d):
        if name == "node_y":
            params[name] = np.ones((rows, cols), np.float32)
        elif name.startswith("dhe_b"):
            params[name] = np.zeros((rows, cols), np.float32)
        else:
            a = 1.0 / np.sqrt(cols)
            params[name] = rng.uniform(-a, a, (rows, cols)).astype(np.float32)
    return params
