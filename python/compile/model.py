"""Layer-2 GNN models in JAX: GCN, GraphSAGE (mean), GAT.

Written from scratch over static COO/padded-CSR edge arrays so the whole
forward lowers into one AOT HLO. The embedding layer (embeddings.compose,
backed by the Pallas gather_combine kernel) provides h^(0) = V (Eq. 3).

Aggregation paths:
* GCN — padded-CSR SpMM via the Pallas ``spmm_padded`` kernel; the Rust
  coordinator supplies adjacency rows padded to K with symmetric-norm
  coefficients 1/sqrt((deg_u+1)(deg_v+1)) including the self loop.
* SAGE — mean aggregation via ``jax.ops.segment_sum`` over COO arrays.
* GAT — single-head attention with edge softmax via segment max/sum; the
  self edge is folded in analytically (no edge-array expansion).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .embeddings import compose
from .kernels.ref import spmm_padded_ref
from .kernels.spmm_padded import spmm_padded


# ---------------------------------------------------------------------------
# parameter specs

def gnn_param_specs(cfg):
    """[(name, (rows, cols))] for the GNN stack (after embedding params)."""
    model = cfg["model"]
    dims = [cfg["d"]] + [cfg["hidden"]] * (cfg["num_layers"] - 1) + [cfg["classes"]]
    specs = []
    for l in range(cfg["num_layers"]):
        din, dout = dims[l], dims[l + 1]
        if model == "gcn":
            specs += [(f"gcn_w{l}", (din, dout)), (f"gcn_b{l}", (1, dout))]
        elif model == "sage":
            specs += [
                (f"sage_self_w{l}", (din, dout)),
                (f"sage_neigh_w{l}", (din, dout)),
                (f"sage_b{l}", (1, dout)),
            ]
        elif model == "gat":
            specs += [
                (f"gat_w{l}", (din, dout)),
                (f"gat_al{l}", (1, dout)),
                (f"gat_ar{l}", (1, dout)),
                (f"gat_b{l}", (1, dout)),
            ]
        else:
            raise ValueError(f"unknown model {model}")
    return specs


def graph_static_specs(cfg):
    """[(name, shape, dtype)] of the graph arrays each model consumes."""
    n, e = cfg["n"], cfg["edges"]
    if cfg["model"] == "gcn":
        k = cfg["pad_k"]
        return [("adj_idx", (n, k), "i32"), ("adj_w", (n, k), "f32")]
    if cfg["model"] == "sage":
        return [("src", (e,), "i32"), ("dst", (e,), "i32"),
                ("inv_deg", (n, 1), "f32")]
    if cfg["model"] == "gat":
        return [("src", (e,), "i32"), ("dst", (e,), "i32")]
    raise ValueError(cfg["model"])


# ---------------------------------------------------------------------------
# layers

def gcn_layer(h, w, b, adj_idx, adj_w, use_pallas, last):
    spmm = spmm_padded if use_pallas else spmm_padded_ref
    agg = spmm(h, adj_idx, adj_w)
    out = agg @ w + b
    return out if last else jax.nn.relu(out)


def sage_layer(h, w_self, w_neigh, b, src, dst, inv_deg, n, last):
    neigh = jax.ops.segment_sum(h[src], dst, num_segments=n) * inv_deg
    out = h @ w_self + neigh @ w_neigh + b
    return out if last else jax.nn.relu(out)


def gat_layer(h, w, al, ar, b, src, dst, n, last):
    wh = h @ w  # [n, dout]
    el = jnp.sum(wh * al, axis=1)  # [n]
    er = jnp.sum(wh * ar, axis=1)  # [n]
    e = jax.nn.leaky_relu(el[src] + er[dst], 0.2)  # [E]
    e_self = jax.nn.leaky_relu(el + er, 0.2)  # [n] self edge
    # numerically stable softmax over {neighbors(dst)} ∪ {self}
    seg_max = jax.ops.segment_max(e, dst, num_segments=n)
    seg_max = jnp.maximum(jnp.where(jnp.isfinite(seg_max), seg_max, -jnp.inf), e_self)
    exp_e = jnp.exp(e - seg_max[dst])
    exp_self = jnp.exp(e_self - seg_max)
    denom = jax.ops.segment_sum(exp_e, dst, num_segments=n) + exp_self
    num = (jax.ops.segment_sum(exp_e[:, None] * wh[src], dst, num_segments=n)
           + exp_self[:, None] * wh)
    out = num / denom[:, None] + b
    return out if last else jax.nn.elu(out)


# ---------------------------------------------------------------------------
# full forward

def forward(cfg, params, statics, use_pallas=True):
    """Logits [n, classes] from embedding params + GNN params + statics."""
    n, d = cfg["n"], cfg["d"]
    h = compose(cfg["embedding"], params, statics, n, d, use_pallas)
    model = cfg["model"]
    for l in range(cfg["num_layers"]):
        last = l == cfg["num_layers"] - 1
        if model == "gcn":
            h = gcn_layer(h, params[f"gcn_w{l}"], params[f"gcn_b{l}"],
                          statics["adj_idx"], statics["adj_w"], use_pallas, last)
        elif model == "sage":
            h = sage_layer(h, params[f"sage_self_w{l}"],
                           params[f"sage_neigh_w{l}"], params[f"sage_b{l}"],
                           statics["src"], statics["dst"], statics["inv_deg"],
                           n, last)
        elif model == "gat":
            h = gat_layer(h, params[f"gat_w{l}"], params[f"gat_al{l}"],
                          params[f"gat_ar{l}"], params[f"gat_b{l}"],
                          statics["src"], statics["dst"], n, last)
    return h


def loss_fn(cfg, params, statics, labels, mask, use_pallas=True):
    """Masked mean loss: softmax-CE (multiclass) or BCE (multilabel)."""
    logits = forward(cfg, params, statics, use_pallas)
    if cfg["task"] == "multiclass":
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
        return jnp.sum(nll * mask) / jnp.sum(mask)
    # multilabel: labels [n, tasks] float {0,1}
    z = logits
    per = jnp.maximum(z, 0.0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z)))
    return jnp.sum(jnp.mean(per, axis=1) * mask) / jnp.sum(mask)
