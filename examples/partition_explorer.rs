//! Partitioner explorer: multilevel k-way quality (edge cut, imbalance,
//! community recovery) across k, vs the RandomPart baseline, on an SBM
//! graph and a heavy-tailed R-MAT graph.
//!
//! ```bash
//! cargo run --release --offline --example partition_explorer
//! ```

use poshashemb::graph::{planted_partition, rmat, PlantedPartitionConfig, RmatConfig};
use poshashemb::partition::{edge_cut, partition, random_partition, PartitionConfig};
use std::time::Instant;

fn main() {
    let (sbm, membership) = planted_partition(&PlantedPartitionConfig {
        n: 20_000,
        communities: 16,
        intra_degree: 12.0,
        inter_degree: 2.0,
        seed: 3,
        ..Default::default()
    });
    println!("SBM: n={} m={}", sbm.num_nodes(), sbm.num_edges());
    println!(
        "| {:>4} | {:>10} | {:>10} | {:>9} | {:>7} | {:>9} |",
        "k",
        "cut",
        "rand cut",
        "imbalance",
        "purity",
        "time"
    );
    for k in [2usize, 4, 8, 16, 32, 64] {
        let t = Instant::now();
        let p = partition(&sbm, &PartitionConfig::with_k(k));
        let elapsed = t.elapsed();
        let rand_cut = edge_cut(&sbm, &random_partition(sbm.num_nodes(), k, 1));
        // purity vs planted communities
        let mut counts = vec![std::collections::HashMap::new(); k];
        for (i, &fp) in p.part.iter().enumerate() {
            *counts[fp as usize].entry(membership[i]).or_insert(0usize) += 1;
        }
        let pure: usize = counts.iter().map(|c| c.values().max().copied().unwrap_or(0)).sum();
        println!(
            "| {:>4} | {:>10.0} | {:>10.0} | {:>9.3} | {:>6.1}% | {:>8.1?} |",
            k,
            p.edge_cut,
            rand_cut,
            p.imbalance,
            100.0 * pure as f64 / sbm.num_nodes() as f64,
            elapsed
        );
    }

    let rg = rmat(&RmatConfig { scale: 14, edge_factor: 8, ..Default::default() });
    println!("\nR-MAT: n={} m={} (heavy-tailed stress test)", rg.num_nodes(), rg.num_edges());
    for k in [8usize, 32] {
        let t = Instant::now();
        let p = partition(&rg, &PartitionConfig::with_k(k));
        let rand_cut = edge_cut(&rg, &random_partition(rg.num_nodes(), k, 1));
        println!(
            "k={k:<3} cut={:.0} (random {:.0}, {:.1}x better) imbalance={:.3} [{:?}]",
            p.edge_cut,
            rand_cut,
            rand_cut / p.edge_cut.max(1.0),
            p.imbalance,
            t.elapsed()
        );
    }
}
