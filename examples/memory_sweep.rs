//! Memory cost-model sweep (no training): prices every method of the
//! paper on each synthetic dataset and prints the savings table the
//! paper quotes (88–97% for PosHashEmb, 90–99% for PosEmb 3-level).
//!
//! ```bash
//! cargo run --release --offline --example memory_sweep
//! ```

use poshashemb::config::{default_c, default_k};
use poshashemb::data::{spec, Dataset, DATASET_NAMES};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan, MemoryReport};
use poshashemb::partition::{Hierarchy, HierarchyConfig};

fn main() {
    for name in DATASET_NAMES {
        let sp = spec(name).unwrap();
        let ds = Dataset::generate(&sp);
        let k = default_k(sp.n);
        let c = default_c(sp.n, k);
        let b = c * k;
        let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(k, 3));
        println!("\n=== {name} (n={}, d={}, k={k}, c={c}, b={b}) ===", sp.n, sp.d);
        println!("| {:<26} | {:>12} | {:>8} | {:>7} |", "Method", "Params", "of full", "Savings");
        let methods: Vec<EmbeddingMethod> = vec![
            EmbeddingMethod::Full,
            EmbeddingMethod::HashTrick { buckets: b },
            EmbeddingMethod::Bloom { buckets: b, h: 2 },
            EmbeddingMethod::HashEmb { buckets: b, h: 2 },
            EmbeddingMethod::PosEmb { levels: 1 },
            EmbeddingMethod::PosEmb { levels: 3 },
            EmbeddingMethod::PosFullEmb { levels: 3 },
            EmbeddingMethod::PosHashEmbInter { levels: 3, buckets: b, h: 2 },
            EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: c, h: 2 },
        ];
        for m in methods {
            let plan = EmbeddingPlan::build(sp.n, sp.d, &m, Some(&hier), 0);
            println!("{}", MemoryReport::from_plan(&plan).row());
        }
    }
    println!("\npaper claim: PosHashEmb saves 88–97%, PosEmb 3-level 90–99% vs FullEmb");
}
