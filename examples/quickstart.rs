//! Quickstart: the PosHashEmb pipeline in five steps, no artifacts
//! required (pure Rust: reference oracle + parallel compose engine).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use poshashemb::embedding::{
    compose_embeddings, init_params, ComposeEngine, EmbeddingMethod, EmbeddingPlan, MemoryReport,
};
use poshashemb::graph::{planted_partition, GraphStats, PlantedPartitionConfig};
use poshashemb::partition::{Hierarchy, HierarchyConfig};

fn main() {
    // 1. A homophilous graph (10k nodes, 20 planted communities).
    let (graph, communities) = planted_partition(&PlantedPartitionConfig {
        n: 10_000,
        communities: 20,
        intra_degree: 12.0,
        inter_degree: 2.0,
        seed: 7,
        ..Default::default()
    });
    let stats = GraphStats::compute(&graph, Some(&communities));
    println!(
        "graph: {} nodes, {} edges, homophily {:.3}",
        stats.num_nodes,
        stats.num_edges,
        stats.edge_homophily.unwrap()
    );

    // 2. Hierarchical k-way partitioning (paper Algorithm 1, line 2).
    //    k = ⌈n^(1/4)⌉ = 10, three levels -> m = [10, 100, 1000].
    let cfg = HierarchyConfig::from_alpha(graph.num_nodes(), 0.25, 3);
    let hierarchy = Hierarchy::build(&graph, &cfg);
    println!(
        "hierarchy: k={} m={:?} ({} partitions total)",
        hierarchy.k,
        hierarchy.m,
        hierarchy.total_partitions()
    );

    // 3. The paper's default method: PosHashEmb Intra (h=2).
    let (method, _) = EmbeddingMethod::paper_default_intra(graph.num_nodes());
    let d = 64;
    let plan = EmbeddingPlan::build(graph.num_nodes(), d, &method, Some(&hierarchy), 0);

    // 4. Memory: the whole point of the paper.
    let report = MemoryReport::from_plan(&plan);
    println!("\n| Method                     | Params       | of full  | Savings |");
    println!("{}", report.row());
    let full = EmbeddingPlan::build(graph.num_nodes(), d, &EmbeddingMethod::Full, None, 0);
    println!("{}", MemoryReport::from_plan(&full).row());

    // 5. Compose node embeddings (v_i = p_i + x_i, Eq. 7) with the
    //    blocked parallel engine, and verify it against the scalar oracle.
    let params = init_params(&plan, 42);
    let engine = ComposeEngine::new(&plan);
    let v = engine.compose_all(&params);
    let oracle = compose_embeddings(&plan, &params);
    assert_eq!(v, oracle, "engine must be bit-identical to the reference");
    let sample: Vec<u32> = vec![0, 17, 4242, 9999];
    let vb = engine.compose_batch(&params, &sample);
    assert_eq!(&vb[..d], &v[..d], "batch row 0 must match full row 0");
    println!(
        "\ncomposed {} x {} embedding matrix; v[0][..4] = {:?}",
        graph.num_nodes(),
        d,
        &v[..4]
    );

    // Homophily check: same-partition nodes have more-similar embeddings.
    let z0 = &plan.position.as_ref().unwrap().z[0];
    let (mut same, mut diff, mut ns, mut nd) = (0f64, 0f64, 0usize, 0usize);
    for i in (0..1000).step_by(7) {
        for j in (1..1000).step_by(11) {
            let dist: f32 = v[i * d..(i + 1) * d]
                .iter()
                .zip(&v[j * d..(j + 1) * d])
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            if z0[i] == z0[j] {
                same += dist as f64;
                ns += 1;
            } else {
                diff += dist as f64;
                nd += 1;
            }
        }
    }
    println!(
        "mean sq-distance: same-partition {:.4} vs cross-partition {:.4}",
        same / ns as f64,
        diff / nd as f64
    );
    println!("\nnext: `make artifacts && cargo run --release --example node_classification`");
}
