//! End-to-end driver (DESIGN.md §6): generate synth-arxiv, build the
//! 3-level hierarchy, train GCN with PosHashEmb Intra (h=2) AND the
//! FullEmb baseline through the full Rust→PJRT→HLO(Pallas) stack, log
//! both loss curves, and report accuracy + memory savings.
//!
//! Requires `make artifacts` (smoke or full grid).
//!
//! ```bash
//! cargo run --release --offline --example node_classification [epochs]
//! ```

use poshashemb::config::full_grid;
use poshashemb::coordinator::{run_experiment, TrainOptions};
use poshashemb::runtime::{Manifest, RuntimeClient};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let epochs: usize =
        std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(80);
    let client = RuntimeClient::cpu()?;
    println!("PJRT platform: {}", client.platform());
    let manifest = Manifest::load(Path::new("artifacts"))?;
    let grid = full_grid();
    let opts = TrainOptions {
        epochs: Some(epochs),
        eval_every: 5,
        patience: 0, // run to completion so the loss curve is full length
        ..Default::default()
    };

    let mut summaries = Vec::new();
    for name in ["arxiv_gcn_intra_h2", "arxiv_gcn_full"] {
        let e = grid.iter().find(|e| e.name == name).expect("config in grid");
        println!("\n=== training {name} ({} epochs, full batch) ===", epochs);
        let out = run_experiment(&client, &manifest, e, 0, &opts)?;
        println!("loss curve (every 5 epochs):");
        for (i, chunk) in out.losses.chunks(5).enumerate() {
            println!("  epoch {:>4}: loss {:.4}", i * 5 + 1, chunk[0]);
        }
        println!(
            "final: test={:.3} val={:.3} params={} savings={:.1}% wall={:?}",
            out.test_metric,
            out.val_metric,
            out.memory.params,
            out.memory.savings_pct,
            out.wall
        );
        summaries.push(out);
    }

    let (pos, full) = (&summaries[0], &summaries[1]);
    println!("\n=== summary (paper's headline claim) ===");
    println!(
        "PosHashEmb Intra(h=2): acc {:.3} with {} params ({:.1}% savings vs FullEmb)",
        pos.test_metric, pos.memory.params, pos.memory.savings_pct
    );
    println!(
        "FullEmb baseline     : acc {:.3} with {} params",
        full.test_metric,
        full.memory.params
    );
    let delta = pos.test_metric - full.test_metric;
    let verdict = if delta >= -0.01 {
        "paper claim HOLDS"
    } else {
        "below paper claim"
    };
    println!(
        "accuracy delta {delta:+.3} at {:.0}x parameter reduction — {verdict}",
        full.memory.params as f64 / pos.memory.params as f64
    );
    Ok(())
}
