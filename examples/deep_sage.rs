//! Deep heads: 2-layer SAGE minibatch training on multi-hop sampled
//! blocks — the `--fanouts 10,5` path, runnable without PJRT artifacts.
//!
//! ```bash
//! cargo run --release --example deep_sage
//! ```
//!
//! Prints per-epoch training loss and the peak compose-row count (the
//! memory invariant: a deep head composes the outermost hop's rows,
//! never the full `n × d` matrix).

use poshashemb::coordinator::{MinibatchOptions, MinibatchTrainer, OptimizerKind};
use poshashemb::data::{spec, Dataset};
use poshashemb::embedding::{EmbeddingMethod, EmbeddingPlan};
use poshashemb::partition::{Hierarchy, HierarchyConfig};
use poshashemb::sampler::{Fanouts, SamplerConfig};

fn main() {
    // A shrunk synth-arxiv analog: same generator and split machinery
    // as the paper-scale specs, small enough for a quick example run.
    let mut s = spec("synth-arxiv").expect("registered dataset");
    s.n = 3_000;
    s.communities = 40;
    s.d = 32;
    let ds = Dataset::generate(&s);
    println!(
        "dataset: n={} d={} classes={} train={}",
        s.n,
        s.d,
        s.classes,
        ds.splits.train.len()
    );

    // The paper's default method family: position levels + intra-pool
    // hashing, over a 3-level hierarchy.
    let k = 7; // ≈ n^(1/4)
    let hier = Hierarchy::build(&ds.graph, &HierarchyConfig::new(k, 3));
    let method = EmbeddingMethod::PosHashEmbIntra { levels: 3, compression: 17, h: 2 };
    let plan = EmbeddingPlan::build(s.n, s.d, &method, Some(&hier), 0);
    println!(
        "method: {} ({} params, {:.0}% savings)",
        method.name(),
        plan.num_params(),
        plan.savings() * 100.0
    );

    // A 2-layer SAGE head: hop 0 samples 10 neighbors per seed (feeds
    // layer 2), hop 1 samples 5 per frontier node (feeds layer 1).
    // The fanout list's length IS the head depth.
    let cfg = SamplerConfig {
        batch_size: 128,
        fanouts: Fanouts::parse("10,5").expect("static fanouts"),
        shuffle: true,
    };
    let opts = MinibatchOptions {
        epochs: 8,
        lr: 0.01,
        optimizer: OptimizerKind::Adam,
        hidden: 32,
        seed: 0,
        ..Default::default()
    };
    let mut trainer = MinibatchTrainer::new(&ds, &plan, cfg, opts).expect("trainer construction");
    println!("head: {} SAGE layers, hidden width 32, pipelined engine\n", trainer.layers());
    let out = trainer.train().expect("training run");

    for (e, loss) in out.losses.iter().enumerate() {
        println!("epoch {:>2}  loss {loss:.4}", e + 1);
    }
    println!(
        "\npeak compose rows: {} of n = {} ({:.1}% of the matrix the paper says not to build)",
        out.peak_compose_rows,
        s.n,
        100.0 * out.peak_compose_rows as f64 / s.n as f64
    );
    println!("val {:.3}  test {:.3}  [{:?}]", out.val_metric, out.test_metric, out.wall);
    assert!(
        out.peak_compose_rows < s.n,
        "deep head composed the full matrix — the memory invariant broke"
    );
    assert!(out.losses.iter().all(|l| l.is_finite()), "non-finite loss");
}
